//! Static analysis over compiled rank programs, scenario configurations,
//! and the simulator sources — three dependency-free passes, run by the
//! `lint` CLI subcommand and (for the program verifier) always-on inside
//! [`crate::engine`] before any compiled program reaches the DES.
//!
//! 1. **Rank-program verifier** ([`verify_rank_program`],
//!    [`verify_lockstep`]): an abstract interpreter over [`Step`] sequences
//!    that proves the Issue/Wait prefetch pipeline well-formed per rank
//!    (no use-before-issue, no WAW double-issue, bounded in-flight depth,
//!    no leaked DMA, no dead or colliding plans, plan bytes conserved) and
//!    the cross-rank `Barrier`/`Collective` sequences deadlock-free for
//!    lockstep (DEP) programs.
//! 2. **Config/scenario linter** ([`lint_spec`],
//!    [`lint_override_roundtrip`]): flags contradictory knob combinations
//!    in a frozen [`ScenarioSpec`] that pass `validate()` but can never do
//!    what they claim, and proves the JSON-override surface round-trips
//!    every `ServingConfig` field.
//! 3. **Determinism source lint** ([`lint_sources`], [`scan_source`]): a
//!    line scanner over `rust/src/` that flags wall-clock reads, ambient
//!    RNG, and iteration-order-unstable hash containers in
//!    simulator-critical modules, outside explicit
//!    `det-lint: allow(<rule>)` comments.
//!
//! DESIGN.md §10 documents the invariants table, the linter rules, and the
//! allowlist convention.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::path::{Path, PathBuf};

use crate::config::{
    apply_json_overrides, serving_override_json, HardwareConfig, HbmBudget, PaperModelConfig,
    ParallelMode, ServingConfig,
};
use crate::dwdp::{plan_bytes, ChunkSpec, CompiledProgram};
use crate::serving::registry;
use crate::serving::{ScenarioKind, ScenarioSpec};
use crate::sim::{PlanKey, Slice, Step};

// ---------------------------------------------------------------------------
// Pass 1: rank-program verifier
// ---------------------------------------------------------------------------

/// Tolerance for plan-byte conservation checks, in bytes.
///
/// `build_copy_plan` accumulates slice sizes in f64; a TDM plan splits a
/// multi-GB shard into hundreds of ~1 MB slices, so the sum carries
/// accumulated rounding on the order of 1e-5 bytes at terabyte scale —
/// far below one byte, far above exact equality.  One shared epsilon, used
/// by the verifier and the `dwdp` unit tests, so the two can never drift
/// into flapping against each other.
pub const PLAN_BYTES_EPS: f64 = 1.0;

/// In-flight bound for compiled DWDP programs: double buffering means one
/// receive buffer is being consumed (its plan already waited on) while at
/// most ONE other plan streams into the second buffer — so at any program
/// point at most one plan is issued-but-unwaited.
pub const DWDP_INFLIGHT_DEPTH: usize = 1;

/// A statically-detected program hazard.  Each variant names the invariant
/// it violates; `rank`/`step` locate the first offending program point.
#[derive(Debug, Clone, PartialEq)]
pub enum VerifyError {
    /// `WaitPrefetch` on a key with no in-flight `IssuePrefetch` (either
    /// never issued, or already waited).
    WaitBeforeIssue { rank: usize, step: usize, key: PlanKey },
    /// `IssuePrefetch` on a key that is already in flight or already
    /// consumed — a WAW hazard on the staging buffer.
    DoubleIssue { rank: usize, step: usize, key: PlanKey },
    /// More plans issued-but-unwaited than the double-buffer depth allows.
    InFlightExceedsDepth { rank: usize, step: usize, depth: usize, in_flight: usize },
    /// An issued plan is never waited on — the program ends with the DMA
    /// still (logically) in flight.
    LeakedPlan { rank: usize, key: PlanKey },
    /// A step references a key with no registered plan.
    UnknownKey { rank: usize, step: usize, key: PlanKey },
    /// A registered plan is never issued by any step.
    DeadPlan { rank: usize, key: PlanKey },
    /// Two plans registered under the same key (e.g. the migration-key
    /// offset trick colliding with the per-layer plan space).
    KeyCollision { rank: usize, key: PlanKey },
    /// Total registered plan bytes do not conserve to the expected remote
    /// shard bytes (tolerance [`PLAN_BYTES_EPS`]).
    PlanBytesMismatch { rank: usize, expected: f64, actual: f64 },
    /// A lockstep (DEP) program diverges from rank 0's
    /// `Barrier`/`Collective` sequence — a guaranteed deadlock.  `step` is
    /// the diverging rank's program index of the first mismatched sync op
    /// (or its program length if the rank runs out of sync ops early).
    LockstepDivergence { rank: usize, step: usize, detail: String },
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            VerifyError::WaitBeforeIssue { rank, step, key } => write!(
                f,
                "rank {rank} step {step}: WaitPrefetch({key:?}) with no in-flight IssuePrefetch"
            ),
            VerifyError::DoubleIssue { rank, step, key } => write!(
                f,
                "rank {rank} step {step}: IssuePrefetch({key:?}) double-issued (WAW hazard)"
            ),
            VerifyError::InFlightExceedsDepth { rank, step, depth, in_flight } => write!(
                f,
                "rank {rank} step {step}: {in_flight} plans in flight exceeds double-buffer depth {depth}"
            ),
            VerifyError::LeakedPlan { rank, key } => {
                write!(f, "rank {rank}: issued plan {key:?} is never waited (leaked DMA)")
            }
            VerifyError::UnknownKey { rank, step, key } => {
                write!(f, "rank {rank} step {step}: key {key:?} has no registered plan")
            }
            VerifyError::DeadPlan { rank, key } => {
                write!(f, "rank {rank}: registered plan {key:?} is never issued (dead plan)")
            }
            VerifyError::KeyCollision { rank, key } => {
                write!(f, "rank {rank}: plan key {key:?} registered twice (key collision)")
            }
            VerifyError::PlanBytesMismatch { rank, expected, actual } => write!(
                f,
                "rank {rank}: plan bytes {actual:.3} do not conserve to expected {expected:.3} \
                 (eps {PLAN_BYTES_EPS})"
            ),
            VerifyError::LockstepDivergence { rank, step, detail } => write!(
                f,
                "rank {rank} step {step}: barrier/collective sequence diverges from rank 0 \
                 ({detail}) — lockstep deadlock"
            ),
        }
    }
}

/// Statically verify one rank's compiled program against its registered
/// plans: abstract-interpret the step sequence tracking the set of
/// in-flight (issued-but-unwaited) and consumed plans.
///
/// `depth` bounds the in-flight count ([`DWDP_INFLIGHT_DEPTH`] for
/// compiled DWDP programs).  `expected_bytes`, when given, asserts total
/// registered plan bytes conserve to the remote shard bytes the chunk
/// specs demanded (tolerance [`PLAN_BYTES_EPS`]).
pub fn verify_rank_program(
    rank: usize,
    steps: &[Step],
    plans: &[(PlanKey, Vec<Slice>)],
    depth: usize,
    expected_bytes: Option<f64>,
) -> Result<(), VerifyError> {
    // Registered-plan table; duplicate registration is a key collision.
    let mut registered: BTreeSet<PlanKey> = BTreeSet::new();
    for (key, _) in plans {
        if !registered.insert(*key) {
            return Err(VerifyError::KeyCollision { rank, key: *key });
        }
    }

    // Byte conservation over the registered plans.
    if let Some(expected) = expected_bytes {
        let actual: f64 = plans.iter().map(|(_, p)| plan_bytes(p)).sum();
        if (actual - expected).abs() > PLAN_BYTES_EPS {
            return Err(VerifyError::PlanBytesMismatch { rank, expected, actual });
        }
    }

    // Abstract interpretation of the Issue/Wait pipeline.
    let mut in_flight: BTreeSet<PlanKey> = BTreeSet::new();
    let mut consumed: BTreeSet<PlanKey> = BTreeSet::new();
    for (i, step) in steps.iter().enumerate() {
        match step {
            Step::IssuePrefetch { key } => {
                if !registered.contains(key) {
                    return Err(VerifyError::UnknownKey { rank, step: i, key: *key });
                }
                if in_flight.contains(key) || consumed.contains(key) {
                    return Err(VerifyError::DoubleIssue { rank, step: i, key: *key });
                }
                in_flight.insert(*key);
                if in_flight.len() > depth {
                    return Err(VerifyError::InFlightExceedsDepth {
                        rank,
                        step: i,
                        depth,
                        in_flight: in_flight.len(),
                    });
                }
            }
            Step::WaitPrefetch { key } => {
                if !registered.contains(key) {
                    return Err(VerifyError::UnknownKey { rank, step: i, key: *key });
                }
                if !in_flight.remove(key) {
                    return Err(VerifyError::WaitBeforeIssue { rank, step: i, key: *key });
                }
                consumed.insert(*key);
            }
            // Compute, barriers, collectives, copies, sleeps, marks carry
            // no plan keys; the cross-rank pass handles barrier hazards.
            _ => {}
        }
    }
    if let Some(key) = in_flight.iter().next() {
        return Err(VerifyError::LeakedPlan { rank, key: *key });
    }
    if let Some(key) = registered.difference(&consumed).next() {
        return Err(VerifyError::DeadPlan { rank, key: *key });
    }
    Ok(())
}

/// Convenience wrapper over a [`CompiledProgram`].
pub fn verify_compiled(
    rank: usize,
    program: &CompiledProgram,
    depth: usize,
    expected_bytes: Option<f64>,
) -> Result<(), VerifyError> {
    verify_rank_program(rank, &program.steps, &program.plans, depth, expected_bytes)
}

/// Remote shard bytes a DWDP rank program must move for `chunks`: one
/// layer's shard per per-layer fetch, all layers' shards per migrated
/// expert (see `dwdp::compile_rank_program`).
pub fn expected_plan_bytes(model: &PaperModelConfig, chunks: &[ChunkSpec]) -> f64 {
    let eb = model.expert_bytes();
    let n_moe = model.n_moe_layers() as f64;
    chunks
        .iter()
        .map(|c| {
            let per_layer: usize = c.fetches_per_layer.iter().map(|f| f.len()).sum();
            per_layer as f64 * eb + c.migration.len() as f64 * eb * n_moe
        })
        .sum()
}

/// The sync footprint of one step, if any.
fn sync_op(step: &Step) -> Option<String> {
    match step {
        Step::Barrier { id } => Some(format!("Barrier({id})")),
        Step::Collective { .. } => Some("Collective".to_string()),
        _ => None,
    }
}

/// Cross-rank deadlock check for lockstep (DEP / coupled) programs: every
/// rank must traverse the identical `Barrier`-id / `Collective` sequence.
/// A divergence — different id, different op, or a rank running out of
/// sync ops early — is a guaranteed deadlock in the DES (and the real
/// runtime), reported with the diverging rank and its program step index.
pub fn verify_lockstep(programs: &[Vec<Step>]) -> Result<(), VerifyError> {
    if programs.len() < 2 {
        return Ok(());
    }
    // (program step index, op) sequence per rank.
    let seqs: Vec<Vec<(usize, String)>> = programs
        .iter()
        .map(|p| {
            p.iter().enumerate().filter_map(|(i, s)| sync_op(s).map(|op| (i, op))).collect()
        })
        .collect();
    let reference = &seqs[0];
    for (rank, seq) in seqs.iter().enumerate().skip(1) {
        for (j, (step, op)) in seq.iter().enumerate() {
            match reference.get(j) {
                Some((_, ref_op)) if ref_op == op => {}
                Some((_, ref_op)) => {
                    return Err(VerifyError::LockstepDivergence {
                        rank,
                        step: *step,
                        detail: format!("{op} vs rank 0's {ref_op}"),
                    });
                }
                None => {
                    return Err(VerifyError::LockstepDivergence {
                        rank,
                        step: *step,
                        detail: format!("{op} after rank 0's sequence ended"),
                    });
                }
            }
        }
        if seq.len() < reference.len() {
            let (_, missing) = &reference[seq.len()];
            return Err(VerifyError::LockstepDivergence {
                rank,
                step: programs[rank].len(),
                detail: format!("program ends before rank 0's {missing}"),
            });
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Pass 2: config/scenario linter
// ---------------------------------------------------------------------------

/// Finding severity: `Error` fails the `lint` CLI (exit 1); `Warning` is
/// reported but non-fatal (used for suspicious-but-intentional combos,
/// e.g. the re-placement sweep's skew-0 no-op contract rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warning,
    Error,
}

/// One linter finding, locatable by scenario label or `file:line`.
#[derive(Debug, Clone)]
pub struct LintFinding {
    pub severity: Severity,
    /// Stable rule id, e.g. `kv-migrate-without-sessions`, `wall-clock`.
    pub rule: &'static str,
    /// Where: a scenario label or a `path:line` source location.
    pub location: String,
    pub message: String,
}

impl fmt::Display for LintFinding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Warning => "warning",
            Severity::Error => "error",
        };
        write!(f, "{sev}[{}] {}: {}", self.rule, self.location, self.message)
    }
}

fn finding(severity: Severity, rule: &'static str, location: &str, message: String) -> LintFinding {
    LintFinding { severity, rule, location: location.to_string(), message }
}

/// Statically lint one frozen scenario: contradictory knob combinations
/// that pass `ServingConfig::validate` but cannot do what they claim.
pub fn lint_spec(spec: &ScenarioSpec) -> Vec<LintFinding> {
    let s = &spec.serving;
    let loc = &spec.label;
    let mut out = Vec::new();

    if s.kv_migrate && !s.sessions {
        out.push(finding(
            Severity::Error,
            "kv-migrate-without-sessions",
            loc,
            "kv_migrate moves KV prefixes between groups, which only exist with sessions on"
                .to_string(),
        ));
    }
    if s.kv_capacity_gb > 0.0 && !s.sessions {
        out.push(finding(
            Severity::Warning,
            "kv-capacity-without-sessions",
            loc,
            format!("kv_capacity_gb {} bounds a prefix cache no scenario path uses", s.kv_capacity_gb),
        ));
    }
    if s.rack_blast_radius && s.racks < 2 {
        out.push(finding(
            Severity::Error,
            "rack-blast-single-rack",
            loc,
            "rack_blast_radius needs racks >= 2 to differ from per-group failures".to_string(),
        ));
    }
    if s.sessions && s.think_time.is_infinite() {
        out.push(finding(
            Severity::Warning,
            "sessions-never-return",
            loc,
            "think_time = inf degenerates sessions to the open loop (no follow-up ever arrives)"
                .to_string(),
        ));
    }
    if s.replacement_interval > 0 && (s.mode != ParallelMode::Dwdp || s.routing_skew == 0.0) {
        out.push(finding(
            Severity::Warning,
            "replacement-noop",
            loc,
            format!(
                "replacement_interval {} is a no-op (mode {}, routing_skew {})",
                s.replacement_interval,
                s.mode.name(),
                s.routing_skew
            ),
        ));
    }

    // Unified HBM budget: the derived partition must leave room for what
    // the knobs ask of it.  Both rules are scoped to `hbm_budget` on — with
    // the budget off the cache is free-floating by design and these combos
    // are legal (if suspicious) legacy configurations.
    if s.hbm_budget {
        let budget = HbmBudget::derive(&spec.hw, &spec.model, s);
        if budget.weight_bytes >= budget.total_bytes {
            out.push(finding(
                Severity::Error,
                "weight-footprint-over-hbm",
                loc,
                format!(
                    "resident expert weights {:.1} GB/rank overflow the {:.1} GB device \
                     (local_experts {}): redundancy leaves nothing for KV or activations",
                    budget.weight_bytes / 1e9,
                    budget.total_bytes / 1e9,
                    s.local_experts
                ),
            ));
        }
        let group_kv_bytes = budget.kv_bytes * s.group_size as f64;
        if s.kv_capacity_gb > 0.0 && s.kv_capacity_gb * 1e9 > group_kv_bytes {
            out.push(finding(
                Severity::Error,
                "kv-capacity-over-hbm",
                loc,
                format!(
                    "kv_capacity_gb {} exceeds the {:.3} GB the group's HBM leaves \
                     after weights and headroom",
                    s.kv_capacity_gb,
                    group_kv_bytes / 1e9
                ),
            ));
        }
    }

    // Re-placement interval beyond the horizon: the epoch boundary can
    // never fire within the work the scenario offers.
    let replace_active =
        s.mode == ParallelMode::Dwdp && s.routing_skew > 0.0 && s.replacement_interval > 0;
    if replace_active {
        let ct = crate::engine::chunk_tokens(s);
        // Lower bound on chunks per request (shortest sampled prompt).
        let min_isl = ((s.isl as f64 * s.isl_ratio) as usize).max(1);
        let chunks_per_req = min_isl.div_ceil(ct).max(1);
        let (per_worker, total) = match &spec.kind {
            ScenarioKind::Context { requests_per_rank } => (*requests_per_rank, *requests_per_rank),
            ScenarioKind::Disagg { n_ctx_groups, n_requests, .. } => {
                (n_requests.div_ceil((*n_ctx_groups).max(1)), *n_requests)
            }
            ScenarioKind::Fleet { n_groups, n_requests, .. } => {
                (n_requests.div_ceil((*n_groups).max(1)), *n_requests)
            }
        };
        if s.replacement_interval >= total * chunks_per_req {
            out.push(finding(
                Severity::Error,
                "replacement-beyond-horizon",
                loc,
                format!(
                    "replacement_interval {} can never fire: at most {} chunk iterations total",
                    s.replacement_interval,
                    total * chunks_per_req
                ),
            ));
        } else if s.replacement_interval >= per_worker * chunks_per_req {
            out.push(finding(
                Severity::Warning,
                "replacement-beyond-horizon",
                loc,
                format!(
                    "replacement_interval {} exceeds the ~{} chunk iterations a balanced worker sees",
                    s.replacement_interval,
                    per_worker * chunks_per_req
                ),
            ));
        }
    }
    out
}

/// Prove the JSON-override surface covers every `ServingConfig` field:
/// serialize a probe config (every field differing from the default)
/// through [`serving_override_json`], apply it onto a default via
/// [`apply_json_overrides`], and require exact equality.  A field missing
/// from either side leaves the default in place and fails the comparison;
/// the probe itself is a struct literal, so a newly added field breaks the
/// build until it is enumerated here.
pub fn lint_override_roundtrip() -> Result<(), String> {
    let probe = ServingConfig {
        mode: ParallelMode::Dep,
        group_size: 3,
        max_num_tokens: 12345,
        isl: 2222,
        osl: 333,
        isl_ratio: 0.44,
        isl_std: 55.0,
        local_experts: 66,
        merge_elim: false,
        tdm: false,
        slice_bytes: 777,
        prefetch_fraction: 0.88,
        routing_skew: 0.99,
        replacement_interval: 11,
        mtbf: 12.0,
        mttr: 13.0,
        requeue_on_failure: true,
        racks: 14,
        inter_rack_gbps: 15.0,
        inter_rack_latency: 16e-6,
        rack_blast_radius: true,
        sessions: true,
        session_turns: 17,
        think_time: 18.0,
        kv_migrate: true,
        kv_capacity_gb: 19.0,
        hbm_budget: true,
        hbm_headroom_frac: 0.21,
        host_offload: true,
        host_gbps: 22.0,
        host_latency: 23e-6,
        seed: 20,
    };
    let json = serving_override_json(&probe);
    let mut hw = HardwareConfig::gb200();
    let mut model = PaperModelConfig::tiny();
    let mut got = ServingConfig::default_context(ParallelMode::Dwdp, 4);
    apply_json_overrides(&json, &mut hw, &mut model, &mut got)
        .map_err(|e| format!("override surface rejects its own encoding: {e}"))?;
    if got != probe {
        return Err(format!(
            "ServingConfig does not round-trip through the JSON override surface:\n \
             sent {probe:?}\n got  {got:?}"
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Pass 3: determinism source lint
// ---------------------------------------------------------------------------

/// Top-level `rust/src` entries exempt from the determinism lint: the CLI
/// and bench harness legitimately read wall clocks, and the PJRT runtime
/// wraps real hardware.  Everything else is simulator-critical.
const LINT_EXEMPT: &[&str] = &["main.rs", "bench", "runtime"];

/// Banned patterns per rule.  Built at runtime from fragments so this
/// file's own pattern table never matches itself when the scanner runs
/// over `analysis/`.
fn banned_patterns() -> Vec<(&'static str, String)> {
    vec![
        ("hash-container", ["Hash", "Map"].concat()),
        ("hash-container", ["Hash", "Set"].concat()),
        ("wall-clock", ["Instant", "::now"].concat()),
        ("wall-clock", ["System", "Time"].concat()),
        ("rng", ["thread", "_rng"].concat()),
    ]
}

/// Scan one source file's contents for banned determinism patterns.
///
/// Rules: `hash-container` (std hash maps/sets — iteration order varies
/// across runs and toolchains, so simulator-critical modules must hold
/// keyed state in `BTreeMap`/`BTreeSet`; possession is flagged because a
/// line scanner cannot prove iteration absent), `wall-clock`
/// (`Instant::now`/`SystemTime`), `rng` (`thread_rng`).  Comment text is
/// ignored.  A finding is suppressed by a `det-lint: allow(<rule>)`
/// comment on the same or the immediately preceding line.
pub fn scan_source(path_label: &str, contents: &str) -> Vec<LintFinding> {
    let patterns = banned_patterns();
    let mut out = Vec::new();
    let mut prev_line: &str = "";
    for (i, line) in contents.lines().enumerate() {
        // Code portion only: everything from `//` on is comment text
        // (doc comments and prose mentioning a banned name stay legal).
        let code = line.split("//").next().unwrap_or("");
        for (rule, pat) in &patterns {
            if !code.contains(pat.as_str()) {
                continue;
            }
            let marker = format!("det-lint: allow({rule})");
            if line.contains(&marker) || prev_line.contains(&marker) {
                continue;
            }
            out.push(finding(
                Severity::Error,
                rule,
                &format!("{path_label}:{}", i + 1),
                format!("banned pattern `{pat}` in simulator-critical module"),
            ));
        }
        prev_line = line;
    }
    out
}

/// Recursively collect `.rs` files under `dir`, sorted for deterministic
/// reporting order.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            rs_files(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Run the determinism lint over every simulator-critical `.rs` file under
/// `src_root` (a `rust/src` directory).  Returns the findings plus the
/// number of files scanned.
pub fn lint_sources(src_root: &Path) -> Result<(Vec<LintFinding>, usize), String> {
    let mut files = Vec::new();
    let mut entries: Vec<PathBuf> = std::fs::read_dir(src_root)
        .map_err(|e| format!("read_dir {}: {e}", src_root.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if LINT_EXEMPT.contains(&name) {
            continue;
        }
        if path.is_dir() {
            rs_files(&path, &mut files)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            files.push(path);
        }
    }
    let mut findings = Vec::new();
    let n = files.len();
    for path in &files {
        let contents =
            std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        let label = path
            .strip_prefix(src_root)
            .map(|p| p.display().to_string())
            .unwrap_or_else(|_| path.display().to_string());
        findings.extend(scan_source(&label, &contents));
    }
    Ok((findings, n))
}

/// Locate the crate's `src/` directory: the compile-time manifest dir
/// (valid whenever the binary runs in the checkout that built it, e.g.
/// CI), else `rust/src` / `src` relative to the working directory.
pub fn default_src_root() -> Option<PathBuf> {
    let baked = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    for cand in [baked, PathBuf::from("rust/src"), PathBuf::from("src")] {
        if cand.join("lib.rs").is_file() {
            return Some(cand);
        }
    }
    None
}

// ---------------------------------------------------------------------------
// Registry-wide driver (the `lint` CLI subcommand)
// ---------------------------------------------------------------------------

/// Requests per rank to compile when statically verifying a spec's
/// programs: enough chunk iterations to cross at least one re-placement
/// epoch boundary when the spec re-places, small otherwise.
fn representative_requests(spec: &ScenarioSpec) -> usize {
    let s = &spec.serving;
    let base = match spec.kind {
        ScenarioKind::Context { requests_per_rank } => requests_per_rank.clamp(1, 4),
        _ => 2,
    };
    let replace_active =
        s.mode == ParallelMode::Dwdp && s.routing_skew > 0.0 && s.replacement_interval > 0;
    if !replace_active {
        return base;
    }
    let ct = crate::engine::chunk_tokens(s);
    let min_isl = ((s.isl as f64 * s.isl_ratio) as usize).max(1);
    let chunks_per_req = min_isl.div_ceil(ct).max(1);
    base.max(s.replacement_interval / chunks_per_req + 1)
}

/// Compile the rank programs a spec's serving config produces (for a
/// representative request count) and verify every one of them — the same
/// always-on check `engine` runs, exercised statically across the whole
/// registry by the `lint` subcommand.  Returns the number of rank
/// programs verified.
pub fn verify_spec_programs(spec: &ScenarioSpec) -> Result<usize, String> {
    let n = representative_requests(spec);
    let group = crate::engine::compile_context_group(&spec.hw, &spec.model, &spec.serving, n)?;
    Ok(group.programs.len())
}

/// Aggregate result of a full lint run.
pub struct LintReport {
    pub findings: Vec<LintFinding>,
    /// Scenario specs built and linted across the registry.
    pub specs_checked: usize,
    /// Rank programs compiled and verified (over deduplicated program
    /// configurations).
    pub programs_verified: usize,
    /// Source files scanned by the determinism lint.
    pub files_scanned: usize,
}

impl LintReport {
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Error).count()
    }
    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warning).count()
    }
}

/// Fingerprint of the fields that shape a spec's compiled rank programs —
/// sweeps vary arrival rates and pool sizes over identical serving
/// configs, so program verification dedups on this.
fn program_signature(spec: &ScenarioSpec, n_requests: usize) -> String {
    format!("{:?}|{:?}|{:?}|{n_requests}", spec.hw, spec.model, spec.serving)
}

/// Run all three passes over the whole registry and the source tree.
///
/// `src_root` of `None` skips the determinism lint (the CLI resolves
/// [`default_src_root`] and treats a miss as an error instead).
pub fn run_full_lint(src_root: Option<&Path>) -> Result<LintReport, String> {
    let mut findings = Vec::new();
    let mut specs_checked = 0usize;
    let mut programs_verified = 0usize;
    let mut seen_programs: BTreeSet<String> = BTreeSet::new();

    // Pass 2 first (cheap): every registry scenario's swept specs.
    let mut specs_by_entry: BTreeMap<&'static str, Vec<ScenarioSpec>> = BTreeMap::new();
    for entry in registry::registry() {
        let specs = (entry.specs)()
            .map_err(|e| format!("scenario {}: building swept specs failed: {e}", entry.id))?;
        specs_checked += specs.len();
        for spec in &specs {
            findings.extend(lint_spec(spec));
        }
        specs_by_entry.insert(entry.id, specs);
    }
    if let Err(e) = lint_override_roundtrip() {
        findings.push(finding(Severity::Error, "override-roundtrip", "config", e));
    }

    // Pass 1: compile + verify every distinct program configuration.
    for (id, specs) in &specs_by_entry {
        for spec in specs {
            let n = representative_requests(spec);
            if !seen_programs.insert(program_signature(spec, n)) {
                continue;
            }
            match verify_spec_programs(spec) {
                Ok(k) => programs_verified += k,
                Err(e) => findings.push(finding(
                    Severity::Error,
                    "program-verify",
                    &format!("{id}: {}", spec.label),
                    e,
                )),
            }
        }
    }

    // Pass 3: determinism lint over the sources.
    let mut files_scanned = 0usize;
    if let Some(root) = src_root {
        let (src_findings, n) = lint_sources(root)?;
        findings.extend(src_findings);
        files_scanned = n;
    }

    Ok(LintReport { findings, specs_checked, programs_verified, files_scanned })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::dwdp;
    use crate::model::ChunkWorkload;
    use crate::placement::ExpertPlacement;
    use crate::util::Rng;

    fn tiny_setup() -> (HardwareConfig, PaperModelConfig, ServingConfig, ExpertPlacement) {
        let hw = HardwareConfig::gb200();
        let m = PaperModelConfig::tiny();
        let mut s = ServingConfig::default_context(ParallelMode::Dwdp, 4);
        s.validate(&m).unwrap();
        let p = ExpertPlacement::minimal(m.n_experts, 4);
        (hw, m, s, p)
    }

    fn compiled(n_chunks: usize) -> (PaperModelConfig, Vec<ChunkSpec>, CompiledProgram) {
        let (hw, m, s, p) = tiny_setup();
        let mut rng = Rng::new(9);
        let w = ChunkWorkload::uniform(1024, 512, &m);
        let chunks: Vec<ChunkSpec> =
            (0..n_chunks).map(|_| ChunkSpec::sample(w, &m, &s, &p, 0, &mut rng)).collect();
        let cp = dwdp::compile_rank_program(&hw, &m, &s, 0, &chunks);
        (m, chunks, cp)
    }

    #[test]
    fn valid_dwdp_program_verifies() {
        let (m, chunks, cp) = compiled(3);
        let expected = expected_plan_bytes(&m, &chunks);
        verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, Some(expected)).unwrap();
    }

    #[test]
    fn valid_migration_program_verifies() {
        let (hw, m, s, p) = tiny_setup();
        let mut rng = Rng::new(3);
        let w = ChunkWorkload::uniform(1024, 512, &m);
        let c0 = ChunkSpec::sample(w, &m, &s, &p, 0, &mut rng);
        let mut c1 = ChunkSpec::sample(w, &m, &s, &p, 0, &mut rng);
        c1.migration = vec![(1, 0), (2, 5)];
        let chunks = vec![c0, c1];
        let cp = dwdp::compile_rank_program(&hw, &m, &s, 0, &chunks);
        let expected = expected_plan_bytes(&m, &chunks);
        verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, Some(expected)).unwrap();
    }

    #[test]
    fn mutation_dropped_wait_is_leaked_plan() {
        let (_, _, mut cp) = compiled(1);
        // Drop the LAST WaitPrefetch: nothing re-fills the pipeline after
        // it, so the final plan stays in flight forever.
        let last_wait = cp
            .steps
            .iter()
            .rposition(|s| matches!(s, Step::WaitPrefetch { .. }))
            .expect("program has waits");
        cp.steps.remove(last_wait);
        let err = verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, None).unwrap_err();
        assert!(matches!(err, VerifyError::LeakedPlan { rank: 0, .. }), "{err}");
    }

    #[test]
    fn mutation_dropped_mid_wait_overflows_depth() {
        let (_, _, mut cp) = compiled(1);
        let first_wait = cp
            .steps
            .iter()
            .position(|s| matches!(s, Step::WaitPrefetch { .. }))
            .expect("program has waits");
        cp.steps.remove(first_wait);
        let err = verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, None).unwrap_err();
        assert!(matches!(err, VerifyError::InFlightExceedsDepth { rank: 0, .. }), "{err}");
    }

    #[test]
    fn mutation_duplicated_issue_is_double_issue() {
        let (_, _, mut cp) = compiled(1);
        let first_issue = cp
            .steps
            .iter()
            .position(|s| matches!(s, Step::IssuePrefetch { .. }))
            .expect("program has issues");
        let dup = cp.steps[first_issue].clone();
        cp.steps.insert(first_issue + 1, dup);
        let err = verify_compiled(0, &cp, 8, None).unwrap_err();
        assert!(matches!(err, VerifyError::DoubleIssue { rank: 0, .. }), "{err}");
    }

    #[test]
    fn mutation_orphaned_plan_is_dead_plan() {
        let (_, _, mut cp) = compiled(1);
        cp.plans.push(((0, 9999), Vec::new()));
        let err = verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, None).unwrap_err();
        assert_eq!(err, VerifyError::DeadPlan { rank: 0, key: (0, 9999) });
    }

    #[test]
    fn mutation_duplicate_key_is_key_collision() {
        let (_, _, mut cp) = compiled(1);
        let key = cp.plans[0].0;
        cp.plans.push((key, Vec::new()));
        let err = verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, None).unwrap_err();
        assert_eq!(err, VerifyError::KeyCollision { rank: 0, key });
    }

    #[test]
    fn mutation_wrong_bytes_is_mismatch() {
        let (m, chunks, cp) = compiled(1);
        let expected = expected_plan_bytes(&m, &chunks) + 10.0;
        let err = verify_compiled(0, &cp, DWDP_INFLIGHT_DEPTH, Some(expected)).unwrap_err();
        assert!(matches!(err, VerifyError::PlanBytesMismatch { rank: 0, .. }), "{err}");
    }

    #[test]
    fn wait_without_issue_and_unknown_key() {
        let plans = vec![((0usize, 0u32), Vec::new())];
        let steps = vec![Step::WaitPrefetch { key: (0, 0) }];
        let err = verify_rank_program(0, &steps, &plans, 1, None).unwrap_err();
        assert_eq!(err, VerifyError::WaitBeforeIssue { rank: 0, step: 0, key: (0, 0) });
        let steps = vec![Step::IssuePrefetch { key: (0, 7) }];
        let err = verify_rank_program(0, &steps, &plans, 1, None).unwrap_err();
        assert_eq!(err, VerifyError::UnknownKey { rank: 0, step: 0, key: (0, 7) });
    }

    #[test]
    fn synthetic_over_depth_is_exceeded() {
        let plans = vec![((0usize, 0u32), Vec::new()), ((0usize, 1u32), Vec::new())];
        let steps = vec![
            Step::IssuePrefetch { key: (0, 0) },
            Step::IssuePrefetch { key: (0, 1) },
            Step::WaitPrefetch { key: (0, 0) },
            Step::WaitPrefetch { key: (0, 1) },
        ];
        let err = verify_rank_program(0, &steps, &plans, 1, None).unwrap_err();
        assert_eq!(
            err,
            VerifyError::InFlightExceedsDepth { rank: 0, step: 1, depth: 1, in_flight: 2 }
        );
        // Depth 2 accepts the same pipeline.
        verify_rank_program(0, &steps, &plans, 2, None).unwrap();
    }

    fn dep_programs() -> Vec<Vec<Step>> {
        let hw = HardwareConfig::gb200();
        let m = PaperModelConfig::tiny();
        let mut s = ServingConfig::default_context(ParallelMode::Dep, 4);
        s.validate(&m).unwrap();
        let w = ChunkWorkload::uniform(1024, 512, &m);
        (0..2).map(|r| crate::dep::compile_rank_program(&hw, &m, &s, r, &[w, w], None)).collect()
    }

    #[test]
    fn lockstep_dep_programs_verify() {
        verify_lockstep(&dep_programs()).unwrap();
    }

    #[test]
    fn mutation_barrier_skew_is_lockstep_divergence() {
        let mut programs = dep_programs();
        // Swap rank 1's first two Barrier ids.
        let barrier_idx: Vec<usize> = programs[1]
            .iter()
            .enumerate()
            .filter_map(|(i, s)| matches!(s, Step::Barrier { .. }).then_some(i))
            .take(2)
            .collect();
        let (a, b) = (barrier_idx[0], barrier_idx[1]);
        programs[1].swap(a, b);
        let err = verify_lockstep(&programs).unwrap_err();
        assert!(
            matches!(err, VerifyError::LockstepDivergence { rank: 1, step, .. } if step == a),
            "{err}"
        );
    }

    #[test]
    fn mutation_truncated_rank_is_lockstep_divergence() {
        let mut programs = dep_programs();
        let last_barrier = programs[1]
            .iter()
            .rposition(|s| matches!(s, Step::Barrier { .. }))
            .expect("dep program has barriers");
        programs[1].truncate(last_barrier);
        let err = verify_lockstep(&programs).unwrap_err();
        assert!(matches!(err, VerifyError::LockstepDivergence { rank: 1, .. }), "{err}");
    }

    /// Satellite: every program compiled across a randomized sweep of
    /// (redundancy x chunk counts x migration epochs x DWDP/DEP) passes
    /// the always-on verifier inside `engine::compile_context_group` —
    /// including the coupled cross-rank lockstep pass.
    #[test]
    fn property_randomized_sweep_compiles_verified() {
        let hw = HardwareConfig::gb200();
        let m = PaperModelConfig::tiny();
        let mut rng = Rng::new(0xA11A);
        for mode in [ParallelMode::Dwdp, ParallelMode::Dep] {
            for &local in &[2usize, 4, 6] {
                for &(skew, interval) in &[(0.0, 0usize), (1.0, 0), (1.0, 2), (1.5, 5)] {
                    let mut s = ServingConfig::default_context(mode, 4);
                    s.local_experts = local;
                    s.routing_skew = skew;
                    s.replacement_interval = interval;
                    s.max_num_tokens = 4096;
                    s.isl = *rng.choose(&[768usize, 1500, 3000]);
                    s.prefetch_fraction = *rng.choose(&[0.15, 0.6, 1.0]);
                    s.tdm = rng.f64() < 0.5;
                    s.merge_elim = rng.f64() < 0.5;
                    s.seed = rng.next_u64();
                    s.validate(&m).unwrap();
                    let n_req = 1 + (rng.next_u64() % 2) as usize;
                    let g = crate::engine::compile_context_group(&hw, &m, &s, n_req)
                        .unwrap_or_else(|e| panic!("{mode:?} local={local} skew={skew}: {e}"));
                    assert_eq!(g.programs.len(), 4);
                }
            }
        }
    }

    #[test]
    fn override_surface_roundtrips_every_field() {
        lint_override_roundtrip().unwrap();
    }

    #[test]
    fn spec_linter_flags_contradictory_combos() {
        let spec = crate::serving::Scenario::fleet()
            .mode(ParallelMode::Dwdp)
            .group(4)
            .groups(2)
            .kv_migrate(true)
            .build()
            .unwrap();
        let findings = lint_spec(&spec);
        assert!(
            findings
                .iter()
                .any(|f| f.rule == "kv-migrate-without-sessions" && f.severity == Severity::Error),
            "{findings:?}"
        );
    }

    /// Mutation tests for the unified-HBM-budget rules: a sane budgeted
    /// config lints clean; mutating the KV override past HBM-after-weights
    /// or the redundancy past the device each trips its rule; with the
    /// budget off both mutations are out of the rules' scope.
    #[test]
    fn spec_linter_flags_hbm_budget_overcommit() {
        let build = |budget: bool, kv_gb: f64, local: usize| {
            crate::serving::Scenario::fleet()
                .mode(ParallelMode::Dwdp)
                .group(4)
                .groups(2)
                .sessions(true)
                .hbm_budget(budget)
                .kv_capacity_gb(kv_gb)
                .local_experts(local)
                .build()
                .unwrap()
        };
        let ok = build(true, 2.0, 64);
        let findings = lint_spec(&ok);
        assert!(
            !findings.iter().any(|f| f.severity == Severity::Error),
            "sane budget must lint clean: {findings:?}"
        );
        // Mutation 1: a per-group KV override far past what the device
        // leaves after weights and headroom.
        let over = build(true, 1e4, 64);
        assert!(
            lint_spec(&over)
                .iter()
                .any(|f| f.rule == "kv-capacity-over-hbm" && f.severity == Severity::Error),
            "{:?}",
            lint_spec(&over)
        );
        // Mutation 2: redundancy whose resident weights alone overflow the
        // device.
        let heavy = build(true, 0.0, 192);
        assert!(
            lint_spec(&heavy)
                .iter()
                .any(|f| f.rule == "weight-footprint-over-hbm" && f.severity == Severity::Error),
            "{:?}",
            lint_spec(&heavy)
        );
        // Budget off: both combos are legacy free-floating configs, out of
        // scope for the budget rules.
        for spec in [build(false, 1e4, 64), build(false, 0.0, 192)] {
            assert!(
                !lint_spec(&spec)
                    .iter()
                    .any(|f| f.rule == "kv-capacity-over-hbm"
                        || f.rule == "weight-footprint-over-hbm"),
                "{:?}",
                lint_spec(&spec)
            );
        }
    }

    #[test]
    fn spec_linter_flags_unreachable_replacement_interval() {
        let spec = crate::serving::Scenario::context()
            .mode(ParallelMode::Dwdp)
            .group(4)
            .requests(1)
            .routing_skew(1.0)
            .replacement_interval(10_000)
            .build()
            .unwrap();
        let findings = lint_spec(&spec);
        assert!(
            findings
                .iter()
                .any(|f| f.rule == "replacement-beyond-horizon" && f.severity == Severity::Error),
            "{findings:?}"
        );
    }

    #[test]
    fn registry_specs_build_and_lint_without_errors() {
        std::env::set_var("DWDP_QUICK", "1");
        let mut total = 0usize;
        for entry in registry::registry() {
            let specs = (entry.specs)().unwrap_or_else(|e| panic!("{}: {e}", entry.id));
            for spec in &specs {
                let findings = lint_spec(spec);
                assert!(
                    !findings.iter().any(|f| f.severity == Severity::Error),
                    "{}: {findings:?}",
                    entry.id
                );
            }
            total += specs.len();
        }
        assert!(total > 50, "registry sweeps should enumerate many specs, got {total}");
    }

    #[test]
    fn scanner_flags_banned_patterns_and_honors_allowlist() {
        let hash_map = ["Hash", "Map"].concat();
        let now = ["Instant", "::now"].concat();
        // Flagged: bare use in code.
        let src = format!("let m = std::collections::{hash_map}::new();\n");
        let f = scan_source("x.rs", &src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "hash-container");
        assert_eq!(f[0].location, "x.rs:1");
        // Suppressed: same-line allow marker.
        let src = format!("let m = {hash_map}::new(); // det-lint: allow(hash-container) keyed\n");
        assert!(scan_source("x.rs", &src).is_empty());
        // Suppressed: preceding-line allow marker.
        let src = format!("// det-lint: allow(wall-clock) real time\nlet t = {now}();\n");
        assert!(scan_source("x.rs", &src).is_empty());
        // A marker for the WRONG rule does not suppress.
        let src = format!("let t = {now}(); // det-lint: allow(rng)\n");
        assert_eq!(scan_source("x.rs", &src).len(), 1);
        // Comment-only mentions are ignored.
        let src = format!("/// docs about {hash_map} iteration\nlet x = 1;\n");
        assert!(scan_source("x.rs", &src).is_empty());
    }

    #[test]
    fn determinism_lint_passes_on_this_source_tree() {
        let root = default_src_root().expect("source tree locatable");
        let (findings, files) = lint_sources(&root).unwrap();
        assert!(files > 20, "expected to scan the crate, saw {files} files");
        assert!(
            findings.is_empty(),
            "unallowlisted determinism findings:\n{}",
            findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    #[test]
    fn verify_spec_programs_covers_context_and_replacement() {
        std::env::set_var("DWDP_QUICK", "1");
        // Tiny-model specs keep this fast while exercising both modes and
        // the migration-epoch path end to end.
        let hw = HardwareConfig::gb200();
        let m = PaperModelConfig::tiny();
        for (mode, skew, interval) in [
            (ParallelMode::Dep, 0.0, 0usize),
            (ParallelMode::Dwdp, 0.0, 0),
            (ParallelMode::Dwdp, 1.0, 3),
        ] {
            let mut s = ServingConfig::default_context(mode, 4);
            s.routing_skew = skew;
            s.replacement_interval = interval;
            s.max_num_tokens = 4096;
            s.isl = 2048;
            s.validate(&m).unwrap();
            let g = crate::engine::compile_context_group(&hw, &m, &s, 2).unwrap();
            assert_eq!(g.programs.len(), 4);
        }
    }
}
