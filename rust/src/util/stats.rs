//! Small statistics helpers used by metrics aggregation and experiments.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than 2 samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (std/mean); 0.0 when the mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Linear-interpolated percentile, p in [0, 100]. Sorts a copy.
///
/// Sorts with `total_cmp` (NaN orders after +inf) — the same total order
/// `LatencyDigest` uses — so a stray NaN sample degrades the top
/// percentiles instead of panicking the whole aggregation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&v, p)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Running summary accumulator (Welford) for streaming metrics.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
        assert!((cv(&xs) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    /// Regression: `partial_cmp().unwrap()` panicked on any NaN sample,
    /// while `LatencyDigest` sorted the same data with `total_cmp`.  Both
    /// now share the total order: NaN sorts last, so low/mid percentiles
    /// of the finite samples are unaffected.
    #[test]
    fn percentile_tolerates_nan_samples() {
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(percentile(&xs, 100.0).is_nan(), "NaN orders last");
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn summary_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - mean(&xs)).abs() < 1e-12);
        assert!((s.std_dev() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn summary_empty_is_zeroed() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std_dev(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }
}
