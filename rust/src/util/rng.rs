//! Deterministic PRNG + distributions.
//!
//! The offline build environment has no `rand` crate, so the library carries
//! its own generator: xoshiro256++ (Blackman & Vigna) seeded via SplitMix64.
//! Everything downstream (workload generation, Monte-Carlo contention
//! checks, property tests) threads explicit seeds through this type, which
//! makes every experiment in EXPERIMENTS.md bit-reproducible.

/// xoshiro256++ PRNG. Not cryptographic; excellent statistical quality and
/// sub-nanosecond generation, which matters for the DES hot loop.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Build a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-rank / per-request
    /// streams that must not correlate).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Normal with given mean / std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson sample (Knuth; fine for the small means used here).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda <= 0.0 {
            return 0;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
            if k > 10_000_000 {
                return k; // pathological lambda guard
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }

    #[test]
    fn range_bounds_inclusive() {
        let mut r = Rng::new(11);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..10_000 {
            let v = r.range_u64(3, 5);
            assert!((3..=5).contains(&v));
            saw_lo |= v == 3;
            saw_hi |= v == 5;
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let mean = (0..n).map(|_| r.poisson(3.5) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.5).abs() < 0.1, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_do_not_correlate() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xa: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
