//! In-tree substrates for facilities the offline registry lacks
//! (rand / serde_json / prettytable equivalents). See DESIGN.md §2.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use json::Json;
pub use rng::Rng;
