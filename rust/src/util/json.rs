//! Minimal JSON parser/serializer (no serde in the offline registry).
//!
//! Scope: everything `aot.py` emits in `manifest.json` plus the Chrome-trace
//! output this library writes.  Full RFC 8259 value grammar, string escapes
//! including `\uXXXX` (with surrogate pairs), and number parsing via the
//! standard `f64` path.  Object key order is preserved.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic iteration for serialization/tests.
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors (None on type mismatch) ----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// `obj["key"]` style access; Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array indexing; Null when out of range / non-array.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for object literals.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{08}'),
                        Some(b'f') => s.push('\u{0C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if !self.bytes[self.pos..].starts_with(b"\\u") {
                                    return Err(self.err("lone high surrogate"));
                                }
                                self.pos += 2;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return Err(self.err("bad low surrogate"));
                                }
                                let cp =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            } else {
                                char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let txt = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn round_trips() {
        let cases = [
            r#"{"a":[1,2.5,{"b":null},true],"c":"x\ny"}"#,
            "[]",
            "{}",
            r#"[-3,0.125,1000000]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let d = v.dump();
            assert_eq!(Json::parse(&d).unwrap(), v, "case {c} -> {d}");
        }
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" { \"a\" :\n[ 1 , 2 ]\t} ").unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integer_dump_has_no_fraction() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.5).dump(), "5.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("artifacts").as_arr().unwrap().len() >= 10);
            assert!(m.get("config").get("hidden").as_usize().unwrap() > 0);
        }
    }
}
