//! Plain-text table rendering for the experiment regenerators.
//!
//! Every `dwdp-repro experiment ...` subcommand prints the same rows the
//! paper's tables report; this module owns alignment and markdown-ish
//! formatting so outputs drop straight into EXPERIMENTS.md.

/// A simple column-aligned table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as a GitHub-flavoured markdown table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(&format!("### {t}\n\n"));
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Serialize as JSON (`--json` export: bench-trajectory capture and
    /// plotting scripts).
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::json::obj;
        use crate::util::Json;
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|row| Json::Arr(row.iter().map(|c| Json::Str(c.clone())).collect()))
            .collect();
        obj(vec![
            (
                "title",
                match &self.title {
                    Some(t) => Json::Str(t.clone()),
                    None => Json::Null,
                },
            ),
            (
                "header",
                Json::Arr(self.header.iter().map(|h| Json::Str(h.clone())).collect()),
            ),
            ("rows", Json::Arr(rows)),
        ])
    }

    /// Render as CSV (for plotting scripts).
    pub fn render_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .header
            .iter()
            .map(|s| esc(s))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with `d` decimals.
pub fn f(x: f64, d: usize) -> String {
    format!("{:.*}", d, x)
}

/// Format a speedup like the paper (e.g. "1.09").
pub fn speedup(x: f64) -> String {
    format!("{x:.2}")
}

/// Format microseconds with 2 decimals.
pub fn us(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a probability as a percentage like the paper's Table 2: fixed
/// decimals for large values, scientific notation for tiny ones.
pub fn pct(p: f64) -> String {
    let v = p * 100.0;
    if v == 0.0 {
        "-".to_string()
    } else if v >= 0.01 {
        let s = format!("{v:.2}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    } else if v >= 0.0001 {
        format!("{v:.5}").trim_end_matches('0').to_string()
    } else {
        format!("{v:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new(&["Config", "C = 1", "C = 2"]).with_title("Demo");
        t.row(vec!["DWDP3".into(), "50.00".into(), "50.00".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| Config | C = 1 | C = 2 |"));
        assert!(s.contains("| DWDP3  | 50.00 | 50.00 |"));
        assert!(s.contains("|--------|"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x,y".into(), "plain".into()]);
        assert_eq!(t.render_csv(), "a,b\n\"x,y\",plain\n");
    }

    #[test]
    fn json_export_round_trips() {
        let mut t = Table::new(&["a", "b"]).with_title("T");
        t.row(vec!["1".into(), "x\"y".into()]);
        let j = t.to_json();
        assert_eq!(j.get("title").as_str(), Some("T"));
        assert_eq!(j.get("header").at(1).as_str(), Some("b"));
        assert_eq!(j.get("rows").at(0).at(1).as_str(), Some("x\"y"));
        let parsed = crate::util::Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed, j);
        // Untitled tables serialize a null title.
        assert_eq!(Table::new(&["a"]).to_json().get("title"), &crate::util::Json::Null);
    }

    #[test]
    fn pct_formats_like_paper() {
        assert_eq!(pct(0.5), "50");
        assert_eq!(pct(0.4444), "44.44");
        assert_eq!(pct(0.1111), "11.11");
        assert_eq!(pct(0.0), "-");
        assert_eq!(pct(0.0000085), "0.00085");
        assert!(pct(3.9e-9).contains('e'));
    }

    #[test]
    fn float_helpers() {
        assert_eq!(f(1.2345, 2), "1.23");
        assert_eq!(speedup(1.091), "1.09");
        assert_eq!(us(161.853), "161.85");
    }
}
