//! Closed-loop session workloads: multi-turn conversations layered on the
//! open-loop arrival processes.
//!
//! Open-loop traffic treats every request as independent; the traffic DWDP
//! actually serves is millions of *users* in multi-turn conversations whose
//! follow-ups share a long prefix (the full session history) with prior
//! turns.  [`SessionGen`] models that loop:
//!
//! * Session openings ride the underlying [`OpenLoopGen`] stream verbatim
//!   (same RNG, same arrivals, same ISL/OSL draws), so a session workload
//!   whose think time is infinite — no user ever returns — degenerates to
//!   the open-loop stream bit-for-bit.
//! * Each opening starts a session whose *plan* (turn count, per-follow-up
//!   fresh prompt tokens, output lengths, think times) is pre-sampled from
//!   a session-keyed RNG stream.  The offered load is therefore a pure
//!   function of the seed — identical under every routing policy — which
//!   is what makes "equal offered load" policy comparisons meaningful.
//! * A follow-up's ISL is the whole prior context (previous ISL + previous
//!   OSL) plus fresh tokens, and it arrives one think time after the
//!   previous response finished streaming: the closed-loop feedback that
//!   an open-loop generator cannot express.
//!
//! The consumer is the cluster simulator ([`crate::fleet`]), which pairs
//! the shared prefix with a per-group KV cache so a follow-up routed back
//! to the group holding its session's KV skips re-prefilling the prefix.

use crate::util::Rng;
use crate::workload::{IslDist, OpenLoopGen, OslDist, Request};

/// Stream tag mixed into the workload seed for per-session plan RNGs.
const SESSION_STREAM: u64 = 0x5E55;
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Frozen per-session schedule, pre-sampled at session creation.
///
/// `turns` counts *all* turns including the opening, so a plan with
/// `turns == 1` has no follow-ups and the per-follow-up vectors are empty;
/// follow-up turn `k` (1-based) reads index `k - 1`.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionPlan {
    /// Total turns in the session, in `[1, max_turns]`.
    pub turns: usize,
    /// Fresh prompt tokens each follow-up adds on top of the prior context.
    pub new_tokens: Vec<usize>,
    /// Output length of each follow-up turn.
    pub osls: Vec<usize>,
    /// Think time before each follow-up, seconds (infinite when the
    /// configured think time is infinite: the user never returns).
    pub thinks: Vec<f64>,
}

/// Closed-loop session generator: an [`OpenLoopGen`] for session openings
/// plus deterministic per-session plans for the follow-up turns.
#[derive(Debug, Clone)]
pub struct SessionGen {
    base: OpenLoopGen,
    isl_dist: IslDist,
    osl_dist: OslDist,
    seed: u64,
    /// Upper bound on turns per session (sampled uniformly in [1, max]).
    pub max_turns: usize,
    /// Mean think time between a response finishing and the follow-up,
    /// seconds.  Infinite ⇒ no follow-ups (open-loop degeneration);
    /// 0 ⇒ instant follow-ups.
    pub think_time: f64,
}

impl SessionGen {
    pub fn new(
        base: OpenLoopGen,
        seed: u64,
        max_turns: usize,
        think_time: f64,
    ) -> SessionGen {
        debug_assert!(max_turns >= 1);
        let isl_dist = base.isl_dist;
        let osl_dist = base.osl_dist;
        SessionGen { base, isl_dist, osl_dist, seed, max_turns, think_time }
    }

    /// Up to `n` session openings: the base open-loop stream verbatim, each
    /// request tagged as turn 0 of a new session keyed by its id.
    pub fn initial_take(&mut self, n: usize) -> Vec<Request> {
        let mut out = self.base.take(n);
        Self::tag_openings(&mut out);
        out
    }

    /// Session openings arriving strictly before `horizon` (see
    /// [`OpenLoopGen::until`] for the lookahead contract).
    pub fn initial_until(&mut self, horizon: f64, cap: usize) -> Vec<Request> {
        let mut out = self.base.until(horizon, cap);
        Self::tag_openings(&mut out);
        out
    }

    fn tag_openings(reqs: &mut [Request]) {
        for r in reqs.iter_mut() {
            r.session = Some(r.id);
            r.turn = Some(0);
        }
    }

    /// The frozen plan for session `sid` — a pure function of (seed, sid),
    /// independent of routing, admission, and simulation order.
    pub fn plan(&self, sid: u64) -> SessionPlan {
        let mut rng = Rng::new(self.seed ^ SESSION_STREAM ^ sid.wrapping_mul(GOLDEN));
        let turns = 1 + rng.below(self.max_turns as u64) as usize;
        let mut new_tokens = Vec::with_capacity(turns - 1);
        let mut osls = Vec::with_capacity(turns - 1);
        let mut thinks = Vec::with_capacity(turns - 1);
        for _ in 1..turns {
            new_tokens.push(self.isl_dist.sample(&mut rng));
            osls.push(self.osl_dist.sample(&mut rng));
            thinks.push(if self.think_time.is_finite() {
                // think_time == 0 ⇒ lambda = ∞ ⇒ a zero draw (instant
                // follow-up); the RNG still advances so plans stay aligned
                // across think-time settings.
                rng.exponential(1.0 / self.think_time)
            } else {
                f64::INFINITY
            });
        }
        SessionPlan { turns, new_tokens, osls, thinks }
    }

    /// The follow-up to `prev`, arriving one think time after `prev`'s
    /// response finished streaming at `response_done`.  `None` when the
    /// plan is exhausted or the user never returns (infinite think time).
    pub fn follow_up(
        &self,
        prev: &Request,
        plan: &SessionPlan,
        id: u64,
        response_done: f64,
    ) -> Option<Request> {
        let k = prev.turn.unwrap_or(0) as usize + 1;
        if k >= plan.turns {
            return None;
        }
        let think = plan.thinks[k - 1];
        if !think.is_finite() {
            return None;
        }
        Some(Request {
            id,
            arrival: response_done + think,
            // The whole prior context re-enters the prompt, plus fresh
            // tokens — the shared prefix a KV cache can skip.
            isl: prev.isl + prev.osl + plan.new_tokens[k - 1],
            osl: plan.osls[k - 1],
            session: prev.session,
            turn: Some(k as u32),
        })
    }
}

/// KV-prefix tokens a completed request leaves behind: its whole context
/// (prompt + generated tokens), which is exactly the prefix its follow-up
/// re-sends.
pub fn resident_prefix(r: &Request) -> usize {
    r.isl + r.osl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ArrivalProcess;

    fn gen(seed: u64, max_turns: usize, think: f64) -> SessionGen {
        let base = OpenLoopGen::new(
            ArrivalProcess::Poisson { rate: 20.0 },
            IslDist::Fixed { isl: 500 },
            OslDist::Uniform { lo: 8, hi: 64 },
            seed,
        );
        SessionGen::new(base, seed, max_turns, think)
    }

    #[test]
    fn openings_ride_the_open_loop_stream_verbatim() {
        let base = OpenLoopGen::new(
            ArrivalProcess::Poisson { rate: 20.0 },
            IslDist::Fixed { isl: 500 },
            OslDist::Uniform { lo: 8, hi: 64 },
            42,
        );
        let reference = base.clone().take(50);
        let openings = gen(42, 4, 2.0).initial_take(50);
        assert_eq!(openings.len(), 50);
        for (o, r) in openings.iter().zip(&reference) {
            assert_eq!(o.session, Some(r.id));
            assert_eq!(o.turn, Some(0));
            assert_eq!(
                (o.id, o.arrival, o.isl, o.osl),
                (r.id, r.arrival, r.isl, r.osl)
            );
        }
    }

    #[test]
    fn plans_are_deterministic_and_bounded() {
        let g = gen(7, 6, 1.5);
        for sid in 0..64u64 {
            let a = g.plan(sid);
            let b = g.plan(sid);
            assert_eq!(a, b);
            assert!((1..=6).contains(&a.turns), "turns {}", a.turns);
            assert_eq!(a.new_tokens.len(), a.turns - 1);
            assert_eq!(a.osls.len(), a.turns - 1);
            assert_eq!(a.thinks.len(), a.turns - 1);
            assert!(a.thinks.iter().all(|t| t.is_finite() && *t >= 0.0));
        }
        // Distinct sessions draw distinct plans (statistically certain for
        // 64 sessions with a 6-way turn count and continuous think times).
        assert!((0..64u64).any(|s| g.plan(s) != g.plan(s + 64)));
    }

    #[test]
    fn follow_up_carries_the_whole_prior_context() {
        let g = gen(3, 5, 2.0);
        let sid = (0..64)
            .find(|&s| g.plan(s).turns >= 3)
            .expect("some session has >= 3 turns");
        let plan = g.plan(sid);
        let first = Request {
            id: sid,
            arrival: 1.0,
            isl: 500,
            osl: 32,
            session: Some(sid),
            turn: Some(0),
        };
        let f1 = g.follow_up(&first, &plan, 1000, 4.0).unwrap();
        assert_eq!(f1.isl, 500 + 32 + plan.new_tokens[0]);
        assert_eq!(f1.osl, plan.osls[0]);
        assert_eq!(f1.session, Some(sid));
        assert_eq!(f1.turn, Some(1));
        assert!((f1.arrival - (4.0 + plan.thinks[0])).abs() < 1e-12);
        assert_eq!(resident_prefix(&first), 532);
        let f2 = g.follow_up(&f1, &plan, 1001, 9.0).unwrap();
        assert_eq!(f2.isl, f1.isl + f1.osl + plan.new_tokens[1]);
        assert_eq!(f2.turn, Some(2));
    }

    #[test]
    fn plan_exhaustion_ends_the_session() {
        let g = gen(11, 4, 2.0);
        let sid = (0..64).find(|&s| g.plan(s).turns == 1).expect("a 1-turn session");
        let plan = g.plan(sid);
        let first = Request {
            id: sid,
            arrival: 0.0,
            isl: 500,
            osl: 8,
            session: Some(sid),
            turn: Some(0),
        };
        assert!(g.follow_up(&first, &plan, 1, 1.0).is_none());
    }

    #[test]
    fn infinite_think_time_means_no_follow_ups() {
        let g = gen(5, 8, f64::INFINITY);
        for sid in 0..32u64 {
            let plan = g.plan(sid);
            assert!(plan.thinks.iter().all(|t| t.is_infinite()));
            let first = Request {
                id: sid,
                arrival: 0.0,
                isl: 500,
                osl: 8,
                session: Some(sid),
                turn: Some(0),
            };
            assert!(g.follow_up(&first, &plan, 1, 1.0).is_none());
        }
    }
}
