//! Open-loop arrival processes and trace-driven workloads for the fleet
//! layer.
//!
//! The per-group stack consumes closed batches ([`super::WorkloadGen`]'s
//! Poisson stream or an offline batch); a *cluster* absorbing live traffic
//! needs open-loop load whose burstiness is a first-class knob.  This
//! module provides:
//!
//! * [`ArrivalProcess`] — Poisson (memoryless), Gamma-renewal bursts
//!   (same mean rate, tunable squared coefficient of variation), a
//!   two-state Markov-modulated Poisson process (calm/storm regimes), and
//!   deterministic replay of a recorded [`WorkloadTrace`].
//! * [`OslDist`] — per-request output-length sampling, pairing with
//!   [`super::IslDist`] for the prompt side.
//! * [`OpenLoopGen`] — an arrival process bound to ISL/OSL distributions,
//!   yielding a reproducible [`Request`] stream.
//! * [`WorkloadTrace`] — JSON read/write (via [`crate::util::Json`]) of a
//!   request stream, byte-identical across a write→read round trip so
//!   traces can be exchanged and replayed exactly.

use crate::util::json::obj;
use crate::util::{Json, Rng};
use crate::workload::{IslDist, Request};

/// Output-length sampling scheme.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OslDist {
    /// Every request generates the same number of tokens.
    Fixed { osl: usize },
    /// Uniform in `[lo, hi]` inclusive.
    Uniform { lo: usize, hi: usize },
}

impl OslDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            OslDist::Fixed { osl } => osl,
            OslDist::Uniform { lo, hi } => rng.range_u64(lo as u64, hi as u64) as usize,
        }
    }

    /// Distribution mean (for load accounting).
    pub fn mean(&self) -> f64 {
        match *self {
            OslDist::Fixed { osl } => osl as f64,
            OslDist::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
        }
    }

    /// Validate the parameters (finite, ordered, non-zero).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            OslDist::Fixed { osl } if osl == 0 => Err("osl must be >= 1".into()),
            OslDist::Uniform { lo, hi } if lo == 0 || lo > hi => {
                Err(format!("osl window [{lo}, {hi}] must satisfy 1 <= lo <= hi"))
            }
            _ => Ok(()),
        }
    }
}

/// Inter-arrival process for open-loop load generation.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Exponential inter-arrivals (CV² = 1): the classic open-loop
    /// benchmark assumption.
    Poisson { rate: f64 },
    /// Gamma-renewal inter-arrivals with squared coefficient of variation
    /// `cv2` at mean rate `rate`.  `cv2 = 1` degenerates to Poisson;
    /// larger values cluster arrivals into bursts separated by lulls —
    /// the dynamic-workload regime where parallelization comparisons flip.
    GammaBurst { rate: f64, cv2: f64 },
    /// Two-state Markov-modulated Poisson process: exponential dwell times
    /// (mean `mean_dwell` seconds) alternate between a calm `rate_low`
    /// regime and a storm `rate_high` regime.
    MarkovModulated { rate_low: f64, rate_high: f64, mean_dwell: f64 },
    /// Deterministic replay of a recorded trace: arrivals *and* per-request
    /// ISL/OSL come from the trace verbatim.
    Replay { trace: WorkloadTrace },
}

impl ArrivalProcess {
    /// Short name for labels and tables.
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson { .. } => "poisson",
            ArrivalProcess::GammaBurst { .. } => "burst",
            ArrivalProcess::MarkovModulated { .. } => "mmpp",
            ArrivalProcess::Replay { .. } => "trace",
        }
    }

    /// Long-run mean arrival rate, req/s.
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate } => *rate,
            ArrivalProcess::GammaBurst { rate, .. } => *rate,
            // Equal expected dwell in both states.
            ArrivalProcess::MarkovModulated { rate_low, rate_high, .. } => {
                (rate_low + rate_high) / 2.0
            }
            ArrivalProcess::Replay { trace } => {
                // n arrivals bound n-1 inter-arrival gaps, and the span
                // runs first-to-last (the old len()/last form both
                // overcounted by one gap and undercounted traces whose
                // first arrival sits far from t = 0).  A single-arrival
                // trace has no gap to estimate a rate from.
                let n = trace.requests.len();
                if n < 2 {
                    return 0.0;
                }
                let first = trace.requests.first().map(|r| r.arrival).unwrap_or(0.0);
                let last = trace.requests.last().map(|r| r.arrival).unwrap_or(0.0);
                let span = last - first;
                if span > 0.0 {
                    (n - 1) as f64 / span
                } else {
                    0.0
                }
            }
        }
    }

    /// Validate the parameters.
    pub fn validate(&self) -> Result<(), String> {
        let pos = |name: &str, v: f64| -> Result<(), String> {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("{name} must be finite and > 0, got {v}"))
            }
        };
        match self {
            ArrivalProcess::Poisson { rate } => pos("arrival rate", *rate),
            ArrivalProcess::GammaBurst { rate, cv2 } => {
                pos("arrival rate", *rate)?;
                if !cv2.is_finite() || *cv2 < 1.0 {
                    return Err(format!("burst cv2 must be >= 1, got {cv2}"));
                }
                Ok(())
            }
            ArrivalProcess::MarkovModulated { rate_low, rate_high, mean_dwell } => {
                pos("rate_low", *rate_low)?;
                pos("rate_high", *rate_high)?;
                pos("mean_dwell", *mean_dwell)
            }
            ArrivalProcess::Replay { trace } => {
                if trace.requests.is_empty() {
                    return Err("replay trace is empty".into());
                }
                for w in trace.requests.windows(2) {
                    if w[1].arrival < w[0].arrival {
                        return Err("replay trace arrivals are not sorted".into());
                    }
                }
                Ok(())
            }
        }
    }
}

/// Unit-scale Gamma(shape) sample — Marsaglia-Tsang for shape >= 1, with
/// the standard `U^(1/k)` boost for shape < 1.
fn gamma_unit(rng: &mut Rng, shape: f64) -> f64 {
    if shape < 1.0 {
        let boost = rng.f64().powf(1.0 / shape);
        return gamma_unit(rng, shape + 1.0) * boost;
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (3.0 * d.sqrt());
    loop {
        let x = rng.gauss();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.f64();
        if u < 1.0 - 0.0331 * x.powi(4) {
            return d * v;
        }
        if u.max(1e-300).ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// Open-loop request stream: an [`ArrivalProcess`] paired with per-request
/// ISL/OSL distributions.  Deterministic for a given seed.
#[derive(Debug, Clone)]
pub struct OpenLoopGen {
    pub process: ArrivalProcess,
    pub isl_dist: IslDist,
    pub osl_dist: OslDist,
    rng: Rng,
    clock: f64,
    next_id: u64,
    /// MMPP regime state: currently in the high-rate storm?
    state_high: bool,
    /// MMPP: absolute time of the next regime switch.
    switch_at: f64,
    /// Replay cursor.
    replay_pos: usize,
    /// Lookahead stashed by [`Self::until`]: the first request at or past a
    /// window's horizon is already drawn (RNG and clock advanced), so it is
    /// held here and returned first by the next call instead of being lost.
    pending: Option<Request>,
}

impl OpenLoopGen {
    pub fn new(process: ArrivalProcess, isl_dist: IslDist, osl_dist: OslDist, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xF1EE7);
        let switch_at = match &process {
            ArrivalProcess::MarkovModulated { mean_dwell, .. } => {
                rng.exponential(1.0 / mean_dwell)
            }
            _ => f64::INFINITY,
        };
        OpenLoopGen {
            process,
            isl_dist,
            osl_dist,
            rng,
            clock: 0.0,
            next_id: 0,
            state_high: false,
            switch_at,
            replay_pos: 0,
            pending: None,
        }
    }

    /// Next arrival instant for the generative processes.
    fn advance_clock(&mut self) {
        match &self.process {
            ArrivalProcess::Poisson { rate } => {
                self.clock += self.rng.exponential(*rate);
            }
            ArrivalProcess::GammaBurst { rate, cv2 } => {
                // Gamma(shape = 1/cv2, scale = cv2/rate): mean 1/rate,
                // CV^2 = cv2.
                let shape = 1.0 / cv2;
                let scale = cv2 / rate;
                self.clock += gamma_unit(&mut self.rng, shape) * scale;
            }
            ArrivalProcess::MarkovModulated { rate_low, rate_high, mean_dwell } => {
                let (rl, rh, dwell) = (*rate_low, *rate_high, *mean_dwell);
                let mut t = self.clock;
                loop {
                    let rate = if self.state_high { rh } else { rl };
                    let gap = self.rng.exponential(rate);
                    if t + gap <= self.switch_at {
                        t += gap;
                        break;
                    }
                    // Regime flips before the candidate arrival: discard it
                    // (memorylessness) and continue in the new regime.
                    t = self.switch_at;
                    self.state_high = !self.state_high;
                    self.switch_at = t + self.rng.exponential(1.0 / dwell);
                }
                self.clock = t;
            }
            ArrivalProcess::Replay { .. } => unreachable!("replay does not advance a clock"),
        }
    }

    /// Next request, or `None` when a replayed trace is exhausted
    /// (generative processes never run dry).
    pub fn next_request(&mut self) -> Option<Request> {
        if let Some(r) = self.pending.take() {
            return Some(r);
        }
        if let ArrivalProcess::Replay { trace } = &self.process {
            let r = trace.requests.get(self.replay_pos)?.clone();
            self.replay_pos += 1;
            return Some(r);
        }
        self.advance_clock();
        let r = Request::open(
            self.next_id,
            self.clock,
            self.isl_dist.sample(&mut self.rng),
            self.osl_dist.sample(&mut self.rng),
        );
        self.next_id += 1;
        Some(r)
    }

    /// Up to `n` requests (fewer only when a replay trace runs out).
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.next_request() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Requests arriving strictly before `horizon` seconds, capped at
    /// `cap` (a runaway guard for storm-heavy processes).
    ///
    /// The first request drawn at or past `horizon` is stashed as a
    /// lookahead (not dropped), so consecutive `until` windows partition
    /// the stream exactly: concatenating the windows reproduces what one
    /// big [`Self::take`] would have produced.
    pub fn until(&mut self, horizon: f64, cap: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while out.len() < cap {
            let Some(r) = self.next_request() else { break };
            if r.arrival >= horizon {
                self.pending = Some(r);
                break;
            }
            out.push(r);
        }
        out
    }
}

/// A recorded request stream: the JSON-interchangeable unit of trace-driven
/// workloads.
///
/// Serialization is canonical — object keys are sorted and numbers use
/// Rust's shortest round-trip float formatting — so `parse(dump(t))` is
/// byte-identical to `dump(t)` (property-tested in
/// `rust/tests/properties.rs`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WorkloadTrace {
    pub requests: Vec<Request>,
}

impl WorkloadTrace {
    pub fn from_requests(requests: Vec<Request>) -> Self {
        WorkloadTrace { requests }
    }

    /// Record `n` requests from a generator into a replayable trace.
    pub fn record(gen: &mut OpenLoopGen, n: usize) -> Self {
        WorkloadTrace { requests: gen.take(n) }
    }

    pub fn to_json(&self) -> Json {
        let requests: Vec<Json> = self
            .requests
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("arrival", Json::Num(r.arrival)),
                    ("id", Json::Num(r.id as f64)),
                    ("isl", Json::Num(r.isl as f64)),
                    ("osl", Json::Num(r.osl as f64)),
                ];
                // Session fields are emitted only when present so
                // pre-session traces stay byte-identical.
                if let Some(s) = r.session {
                    fields.push(("session", Json::Num(s as f64)));
                }
                if let Some(t) = r.turn {
                    fields.push(("turn", Json::Num(t as f64)));
                }
                obj(fields)
            })
            .collect();
        obj(vec![
            ("requests", Json::Arr(requests)),
            ("version", Json::Num(1.0)),
        ])
    }

    pub fn from_json(json: &Json) -> Result<WorkloadTrace, String> {
        if json.get("version").as_f64() != Some(1.0) {
            return Err("unsupported or missing trace version (want 1)".into());
        }
        let rows = json
            .get("requests")
            .as_arr()
            .ok_or_else(|| "trace has no \"requests\" array".to_string())?;
        let mut requests = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let field = |name: &str| -> Result<f64, String> {
                row.get(name)
                    .as_f64()
                    .ok_or_else(|| format!("request {i}: missing numeric \"{name}\""))
            };
            // Integer fields must be genuine naturals — `as usize` would
            // silently saturate negatives to 0 and truncate fractions,
            // turning a corrupted trace into a plausible-looking workload.
            let nat = |name: &str, min: u64| -> Result<u64, String> {
                let v = field(name)?;
                if !v.is_finite() || v.fract() != 0.0 || v < min as f64 || v > 2f64.powi(53) {
                    return Err(format!("request {i}: {name} must be an integer >= {min}, got {v}"));
                }
                Ok(v as u64)
            };
            let arrival = field("arrival")?;
            if !arrival.is_finite() || arrival < 0.0 {
                return Err(format!("request {i}: bad arrival {arrival}"));
            }
            // Optional session fields: absent in pre-session traces, which
            // must keep parsing; present-but-malformed still errors.
            let session = if *row.get("session") == Json::Null {
                None
            } else {
                Some(nat("session", 0)?)
            };
            let turn = if *row.get("turn") == Json::Null {
                None
            } else {
                let t = nat("turn", 0)?;
                Some(u32::try_from(t).map_err(|_| {
                    format!("request {i}: turn {t} does not fit in u32")
                })?)
            };
            requests.push(Request {
                id: nat("id", 0)?,
                arrival,
                isl: nat("isl", 1)? as usize,
                osl: nat("osl", 1)? as usize,
                session,
                turn,
            });
        }
        Ok(WorkloadTrace { requests })
    }

    /// Canonical serialization (see type docs).
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    pub fn parse(text: &str) -> Result<WorkloadTrace, String> {
        let json = Json::parse(text).map_err(|e| format!("trace: {e}"))?;
        WorkloadTrace::from_json(&json)
    }

    pub fn write_file(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.dump()).map_err(|e| format!("write {path}: {e}"))
    }

    pub fn read_file(path: &str) -> Result<WorkloadTrace, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        WorkloadTrace::parse(&text)
    }

    /// Total prompt tokens in the trace.
    pub fn total_isl(&self) -> usize {
        self.requests.iter().map(|r| r.isl).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn fixed_dists() -> (IslDist, OslDist) {
        (IslDist::Fixed { isl: 1000 }, OslDist::Fixed { osl: 64 })
    }

    #[test]
    fn poisson_matches_legacy_rate() {
        let (isl, osl) = fixed_dists();
        let mut g = OpenLoopGen::new(ArrivalProcess::Poisson { rate: 40.0 }, isl, osl, 1);
        let reqs = g.take(4000);
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 40.0).abs() < 3.0, "rate {rate}");
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn gamma_burst_keeps_mean_rate_but_raises_variance() {
        let (isl, osl) = fixed_dists();
        let gaps = |process: ArrivalProcess| -> Vec<f64> {
            let mut g = OpenLoopGen::new(process, isl, osl, 2);
            let reqs = g.take(6000);
            reqs.windows(2).map(|w| w[1].arrival - w[0].arrival).collect()
        };
        let poisson = gaps(ArrivalProcess::Poisson { rate: 20.0 });
        let burst = gaps(ArrivalProcess::GammaBurst { rate: 20.0, cv2: 8.0 });
        let mean_p = stats::mean(&poisson);
        let mean_b = stats::mean(&burst);
        assert!((mean_b - mean_p).abs() / mean_p < 0.15, "{mean_b} vs {mean_p}");
        let cv2_b = stats::cv(&burst).powi(2);
        assert!(cv2_b > 4.0, "burst cv2 {cv2_b} should be >> 1");
    }

    #[test]
    fn mmpp_rate_between_regimes() {
        let (isl, osl) = fixed_dists();
        let p = ArrivalProcess::MarkovModulated {
            rate_low: 2.0,
            rate_high: 50.0,
            mean_dwell: 0.5,
        };
        assert!((p.mean_rate() - 26.0).abs() < 1e-12);
        let mut g = OpenLoopGen::new(p, isl, osl, 3);
        let reqs = g.take(8000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let rate = reqs.len() as f64 / reqs.last().unwrap().arrival;
        assert!(rate > 2.0 && rate < 50.0, "mmpp rate {rate}");
    }

    /// Regression for the replay-rate fencepost: 5 arrivals spaced 0.5 s
    /// apart are 4 gaps over 2 s — 2 req/s — regardless of where the
    /// trace starts on the clock.  The old `len()/last` form reported
    /// 2.5 req/s from t = 0 and a nonsense 0.45 req/s for the same trace
    /// shifted to start at t = 9.
    #[test]
    fn replay_mean_rate_counts_gaps_not_arrivals() {
        let spaced = |t0: f64| {
            WorkloadTrace::from_requests(
                (0..5)
                    .map(|i| Request::open(i, t0 + i as f64 * 0.5, 100, 1))
                    .collect(),
            )
        };
        for t0 in [0.0, 9.0] {
            let p = ArrivalProcess::Replay { trace: spaced(t0) };
            assert!((p.mean_rate() - 2.0).abs() < 1e-12, "t0={t0}: {}", p.mean_rate());
        }
        // Degenerate traces report no rate instead of a bogus one.
        let single = WorkloadTrace::from_requests(vec![Request::open(0, 3.0, 100, 1)]);
        assert_eq!(ArrivalProcess::Replay { trace: single }.mean_rate(), 0.0);
        let storm = WorkloadTrace::from_requests(
            (0..4).map(|i| Request::open(i, 1.0, 100, 1)).collect(),
        );
        assert_eq!(ArrivalProcess::Replay { trace: storm }.mean_rate(), 0.0);
    }

    #[test]
    fn replay_returns_trace_verbatim_then_dry() {
        let trace = WorkloadTrace::from_requests(vec![
            Request::open(7, 0.5, 123, 9),
            Request::open(8, 1.25, 456, 11),
        ]);
        let (isl, osl) = fixed_dists();
        let mut g =
            OpenLoopGen::new(ArrivalProcess::Replay { trace: trace.clone() }, isl, osl, 4);
        let out = g.take(10);
        assert_eq!(out, trace.requests);
        assert!(g.next_request().is_none());
    }

    #[test]
    fn until_respects_horizon_and_cap() {
        let (isl, osl) = fixed_dists();
        let mut g = OpenLoopGen::new(ArrivalProcess::Poisson { rate: 100.0 }, isl, osl, 5);
        let reqs = g.until(1.0, 10_000);
        assert!(!reqs.is_empty());
        assert!(reqs.iter().all(|r| r.arrival < 1.0));
        let mut g2 = OpenLoopGen::new(ArrivalProcess::Poisson { rate: 100.0 }, isl, osl, 5);
        assert_eq!(g2.until(1.0, 3).len(), 3);
    }

    /// Regression for the `until` fencepost: the first request drawn at or
    /// past the horizon used to be dropped (RNG and clock already
    /// advanced), so consecutive windows lost one request per call.
    #[test]
    fn until_windows_partition_the_stream() {
        let (isl, osl) = fixed_dists();
        for process in [
            ArrivalProcess::Poisson { rate: 50.0 },
            ArrivalProcess::GammaBurst { rate: 50.0, cv2: 6.0 },
            ArrivalProcess::MarkovModulated {
                rate_low: 10.0,
                rate_high: 90.0,
                mean_dwell: 0.2,
            },
        ] {
            let mut windows = OpenLoopGen::new(process.clone(), isl, osl, 7);
            let mut chunked = Vec::new();
            for w in 1..=8 {
                chunked.extend(windows.until(w as f64 * 0.25, usize::MAX));
            }
            let mut whole = OpenLoopGen::new(process.clone(), isl, osl, 7);
            let reference = whole.take(chunked.len());
            assert_eq!(chunked, reference, "{}", process.name());
        }
        // Replay traces partition the same way: the overshoot request is
        // handed to the next window instead of being skipped.
        let trace = WorkloadTrace::from_requests(
            (0..6).map(|i| Request::open(i, i as f64, 100, 1)).collect(),
        );
        let mut g = OpenLoopGen::new(
            ArrivalProcess::Replay { trace: trace.clone() },
            isl,
            osl,
            8,
        );
        let mut chunked = g.until(2.5, usize::MAX);
        chunked.extend(g.until(100.0, usize::MAX));
        assert_eq!(chunked, trace.requests);
    }

    #[test]
    fn session_fields_round_trip_and_stay_optional() {
        let mut reqs = vec![Request::open(0, 0.0, 64, 8)];
        reqs.push(Request {
            id: 1,
            arrival: 0.5,
            isl: 128,
            osl: 16,
            session: Some(0),
            turn: Some(1),
        });
        let trace = WorkloadTrace::from_requests(reqs);
        let text = trace.dump();
        assert!(text.contains("\"session\":0") && text.contains("\"turn\":1"));
        let parsed = WorkloadTrace::parse(&text).unwrap();
        assert_eq!(parsed, trace);
        assert_eq!(parsed.dump(), text, "round trip must be byte-identical");
        // Open-loop rows do not grow the new keys.
        assert!(!text[..text.find("session").unwrap()].contains("turn"));
    }

    /// Pre-session traces (no `session`/`turn` keys) must keep parsing.
    #[test]
    fn pre_session_trace_still_parses() {
        let text =
            r#"{"requests":[{"arrival":0.25,"id":3,"isl":77,"osl":9}],"version":1}"#;
        let trace = WorkloadTrace::parse(text).unwrap();
        assert_eq!(trace.requests, vec![Request::open(3, 0.25, 77, 9)]);
        assert_eq!(trace.dump(), text, "legacy shape is the canonical shape");
        // Present-but-malformed session fields still error.
        for row in [
            r#"{"arrival":0,"id":0,"isl":1,"osl":1,"session":-1}"#,
            r#"{"arrival":0,"id":0,"isl":1,"osl":1,"session":0,"turn":0.5}"#,
            r#"{"arrival":0,"id":0,"isl":1,"osl":1,"turn":5000000000}"#,
        ] {
            let text = format!(r#"{{"version":1,"requests":[{row}]}}"#);
            assert!(WorkloadTrace::parse(&text).is_err(), "accepted: {row}");
        }
    }

    #[test]
    fn trace_json_round_trips_exactly() {
        let (isl, _) = fixed_dists();
        let mut g = OpenLoopGen::new(
            ArrivalProcess::GammaBurst { rate: 10.0, cv2: 4.0 },
            isl,
            OslDist::Uniform { lo: 8, hi: 256 },
            6,
        );
        let trace = WorkloadTrace::record(&mut g, 50);
        let text = trace.dump();
        let parsed = WorkloadTrace::parse(&text).unwrap();
        assert_eq!(parsed, trace);
        assert_eq!(parsed.dump(), text, "round trip must be byte-identical");
    }

    #[test]
    fn trace_rejects_malformed_json() {
        assert!(WorkloadTrace::parse("{}").is_err());
        assert!(WorkloadTrace::parse(r#"{"version":1}"#).is_err());
        assert!(WorkloadTrace::parse(r#"{"version":2,"requests":[]}"#).is_err());
        let bad_rows = [
            r#"{"arrival":-1,"id":0,"isl":1,"osl":1}"#, // negative arrival
            r#"{"arrival":0,"id":0,"isl":0,"osl":1}"#,  // zero-token prompt
            r#"{"arrival":0,"id":0,"isl":-100,"osl":1}"#, // negative isl
            r#"{"arrival":0,"id":0,"isl":0.5,"osl":1}"#, // fractional isl
            r#"{"arrival":0,"id":0,"isl":1,"osl":0}"#,  // zero-token output
            r#"{"arrival":0,"id":-1,"isl":1,"osl":1}"#, // negative id
            r#"{"arrival":0,"id":0,"isl":1}"#,          // missing field
        ];
        for row in bad_rows {
            let text = format!(r#"{{"version":1,"requests":[{row}]}}"#);
            assert!(WorkloadTrace::parse(&text).is_err(), "accepted: {row}");
        }
        assert!(
            WorkloadTrace::parse(r#"{"version":1,"requests":[{"arrival":0,"id":0,"isl":1,"osl":1}]}"#)
                .is_ok()
        );
    }

    #[test]
    fn validate_flags_bad_parameters() {
        assert!(ArrivalProcess::Poisson { rate: 0.0 }.validate().is_err());
        assert!(ArrivalProcess::GammaBurst { rate: 1.0, cv2: 0.5 }.validate().is_err());
        assert!(ArrivalProcess::MarkovModulated {
            rate_low: 1.0,
            rate_high: 2.0,
            mean_dwell: 0.0
        }
        .validate()
        .is_err());
        assert!(ArrivalProcess::Replay { trace: WorkloadTrace::default() }
            .validate()
            .is_err());
        let unsorted = WorkloadTrace::from_requests(vec![
            Request::open(0, 2.0, 1, 1),
            Request::open(1, 1.0, 1, 1),
        ]);
        assert!(ArrivalProcess::Replay { trace: unsorted }.validate().is_err());
        assert!(OslDist::Uniform { lo: 0, hi: 4 }.validate().is_err());
        assert!(OslDist::Fixed { osl: 0 }.validate().is_err());
        assert!(OslDist::Uniform { lo: 2, hi: 4 }.validate().is_ok());
    }

    #[test]
    fn same_seed_same_stream() {
        let (isl, osl) = fixed_dists();
        let p = ArrivalProcess::GammaBurst { rate: 5.0, cv2: 6.0 };
        let a = OpenLoopGen::new(p.clone(), isl, osl, 42).take(100);
        let b = OpenLoopGen::new(p, isl, osl, 42).take(100);
        assert_eq!(a, b);
    }
}
