//! Workload generation: requests with ISL/OSL distributions, Poisson
//! arrivals, and expert-routing skew.
//!
//! Mirrors the paper's two datasets parametrically:
//! * Artificial-Analysis-style (context-only ablations): fixed ISL with
//!   either a uniform "ratio window" (`isl_ratio`, Fig. 1 / Table 1/4) or a
//!   normal spread (`isl_std`, Table 3c).
//! * SemiAnalysis-style (end-to-end): ISL in [0.8·8K, 8K], OSL 1K.
//!
//! Open-loop fleet traffic (bursty [`ArrivalProcess`] variants, byte-exact
//! [`WorkloadTrace`] record/replay) lives in [`arrival`]; the consumer is
//! the cluster simulator in [`crate::fleet`].

pub mod arrival;
pub mod session;

use crate::config::ServingConfig;
use crate::util::Rng;

pub use arrival::{ArrivalProcess, OpenLoopGen, OslDist, WorkloadTrace};
pub use session::{SessionGen, SessionPlan};

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// Arrival time, seconds.
    pub arrival: f64,
    /// Input sequence length (prompt tokens).
    pub isl: usize,
    /// Output sequence length (tokens to generate).
    pub osl: usize,
    /// Session this request belongs to (closed-loop workloads; `None` for
    /// plain open-loop traffic).
    pub session: Option<u64>,
    /// Zero-based turn index within the session (`Some(0)` = opening turn).
    pub turn: Option<u32>,
}

impl Request {
    /// An open-loop request with no session membership — the constructor
    /// every pre-session call site uses.
    pub fn open(id: u64, arrival: f64, isl: usize, osl: usize) -> Request {
        Request { id, arrival, isl, osl, session: None, turn: None }
    }

    /// Is this a session follow-up (turn > 0) whose prompt shares a prefix
    /// with its session history?
    pub fn is_follow_up(&self) -> bool {
        self.turn.is_some_and(|t| t > 0)
    }
}

/// ISL sampling scheme.
#[derive(Debug, Clone, Copy)]
pub enum IslDist {
    /// Uniform in [ratio·isl, isl] — the paper's "input ratio".
    RatioWindow { isl: usize, ratio: f64 },
    /// Normal(isl, std), clamped to [1, 2·isl] — the paper's Table 3c.
    Normal { isl: usize, std: f64 },
    /// Every request identical.
    Fixed { isl: usize },
}

impl IslDist {
    /// Build from a serving config (std takes precedence, as in the paper).
    pub fn from_serving(s: &ServingConfig) -> IslDist {
        if s.isl_std > 0.0 {
            IslDist::Normal { isl: s.isl, std: s.isl_std }
        } else if s.isl_ratio < 1.0 {
            IslDist::RatioWindow { isl: s.isl, ratio: s.isl_ratio }
        } else {
            IslDist::Fixed { isl: s.isl }
        }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            IslDist::RatioWindow { isl, ratio } => {
                let lo = (isl as f64 * ratio).round().max(1.0) as usize;
                rng.range_u64(lo as u64, isl as u64) as usize
            }
            IslDist::Normal { isl, std } => {
                let v = rng.normal(isl as f64, std);
                v.round().clamp(1.0, 2.0 * isl as f64) as usize
            }
            IslDist::Fixed { isl } => isl,
        }
    }

    /// Distribution mean (for rate calculations).
    pub fn mean(&self) -> f64 {
        match *self {
            IslDist::RatioWindow { isl, ratio } => isl as f64 * (1.0 + ratio) / 2.0,
            IslDist::Normal { isl, .. } => isl as f64,
            IslDist::Fixed { isl } => isl as f64,
        }
    }
}

/// Generates a request stream.
#[derive(Debug, Clone)]
pub struct WorkloadGen {
    pub isl_dist: IslDist,
    pub osl: usize,
    /// Poisson arrival rate, requests/second. 0 ⇒ all arrive at t=0
    /// (closed-loop offline benchmark).
    pub arrival_rate: f64,
    rng: Rng,
    next_id: u64,
    clock: f64,
}

impl WorkloadGen {
    pub fn new(isl_dist: IslDist, osl: usize, arrival_rate: f64, seed: u64) -> Self {
        WorkloadGen {
            isl_dist,
            osl,
            arrival_rate,
            rng: Rng::new(seed),
            next_id: 0,
            clock: 0.0,
        }
    }

    pub fn from_serving(s: &ServingConfig, arrival_rate: f64) -> Self {
        WorkloadGen::new(IslDist::from_serving(s), s.osl, arrival_rate, s.seed)
    }

    /// Next request in the stream.
    pub fn next_request(&mut self) -> Request {
        if self.arrival_rate > 0.0 {
            self.clock += self.rng.exponential(self.arrival_rate);
        }
        let r = Request::open(
            self.next_id,
            self.clock,
            self.isl_dist.sample(&mut self.rng),
            self.osl,
        );
        self.next_id += 1;
        r
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// Expert-routing skew model: how many tokens each expert receives.
///
/// `skew = 0` is uniform routing; larger values concentrate load on "hot"
/// experts via a Zipf-like weighting — the paper's weight-level imbalance
/// (Fig. 1a).
#[derive(Debug, Clone)]
pub struct RoutingSkew {
    pub n_experts: usize,
    pub top_k: usize,
    /// Zipf exponent; 0 = uniform.
    pub skew: f64,
    weights: Vec<f64>,
}

impl RoutingSkew {
    pub fn new(n_experts: usize, top_k: usize, skew: f64) -> Self {
        let weights: Vec<f64> = (0..n_experts)
            .map(|i| 1.0 / ((i + 1) as f64).powf(skew))
            .collect();
        RoutingSkew { n_experts, top_k, skew, weights }
    }

    /// Sample per-expert token counts for a chunk of `tokens` tokens.
    /// Each token picks `top_k` distinct experts by weighted sampling.
    pub fn sample_loads(&self, tokens: usize, rng: &mut Rng) -> Vec<usize> {
        let mut loads = vec![0usize; self.n_experts];
        let total: f64 = self.weights.iter().sum();
        for _ in 0..tokens {
            let mut chosen = [usize::MAX; 16];
            debug_assert!(self.top_k <= 16);
            for slot in 0..self.top_k {
                // Weighted draw with rejection on duplicates.
                loop {
                    let mut x = rng.f64() * total;
                    let mut e = 0;
                    for (i, w) in self.weights.iter().enumerate() {
                        x -= w;
                        if x <= 0.0 {
                            e = i;
                            break;
                        }
                    }
                    if !chosen[..slot].contains(&e) {
                        chosen[slot] = e;
                        loads[e] += 1;
                        break;
                    }
                }
            }
        }
        loads
    }

    /// Number of *distinct* experts activated by a chunk (drives on-demand
    /// prefetch volume).
    pub fn sample_activated(&self, tokens: usize, rng: &mut Rng) -> usize {
        self.sample_loads(tokens, rng).iter().filter(|&&l| l > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ParallelMode, ServingConfig};
    use crate::util::stats;

    #[test]
    fn ratio_window_bounds() {
        let d = IslDist::RatioWindow { isl: 8192, ratio: 0.8 };
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let v = d.sample(&mut rng);
            assert!((6554..=8192).contains(&v), "{v}");
        }
        assert!((d.mean() - 7372.8).abs() < 0.1);
    }

    #[test]
    fn normal_dist_statistics() {
        let d = IslDist::Normal { isl: 16384, std: 2048.0 };
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| d.sample(&mut rng) as f64).collect();
        assert!((stats::mean(&xs) - 16384.0).abs() < 60.0);
        assert!((stats::std_dev(&xs) - 2048.0).abs() < 60.0);
    }

    #[test]
    fn fixed_dist_is_fixed() {
        let d = IslDist::Fixed { isl: 1024 };
        let mut rng = Rng::new(3);
        assert!((0..100).all(|_| d.sample(&mut rng) == 1024));
    }

    #[test]
    fn from_serving_prefers_std() {
        let mut s = ServingConfig::default_context(ParallelMode::Dwdp, 4);
        s.isl_std = 1024.0;
        assert!(matches!(IslDist::from_serving(&s), IslDist::Normal { .. }));
        s.isl_std = 0.0;
        assert!(matches!(IslDist::from_serving(&s), IslDist::RatioWindow { .. }));
        s.isl_ratio = 1.0;
        assert!(matches!(IslDist::from_serving(&s), IslDist::Fixed { .. }));
    }

    #[test]
    fn poisson_arrivals_monotone_and_rate_correct() {
        let mut g = WorkloadGen::new(IslDist::Fixed { isl: 100 }, 10, 50.0, 4);
        let reqs = g.take(5000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let duration = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / duration;
        assert!((rate - 50.0).abs() < 3.0, "rate {rate}");
    }

    #[test]
    fn zero_rate_means_offline_batch() {
        let mut g = WorkloadGen::new(IslDist::Fixed { isl: 100 }, 10, 0.0, 5);
        assert!(g.take(100).iter().all(|r| r.arrival == 0.0));
    }

    #[test]
    fn ids_unique_and_sequential() {
        let mut g = WorkloadGen::new(IslDist::Fixed { isl: 1 }, 1, 0.0, 6);
        let ids: Vec<u64> = g.take(10).iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_routing_balances() {
        let rs = RoutingSkew::new(32, 4, 0.0);
        let mut rng = Rng::new(7);
        let loads = rs.sample_loads(8000, &mut rng);
        let total: usize = loads.iter().sum();
        assert_eq!(total, 32_000);
        let xs: Vec<f64> = loads.iter().map(|&l| l as f64).collect();
        assert!(stats::cv(&xs) < 0.1, "cv {}", stats::cv(&xs));
    }

    #[test]
    fn skewed_routing_concentrates() {
        let rs = RoutingSkew::new(32, 4, 1.2);
        let mut rng = Rng::new(8);
        let loads = rs.sample_loads(4000, &mut rng);
        // Hot expert 0 gets far more than the tail.
        assert!(loads[0] > loads[31] * 5, "{loads:?}");
        let xs: Vec<f64> = loads.iter().map(|&l| l as f64).collect();
        assert!(stats::cv(&xs) > 0.5);
    }

    #[test]
    fn topk_distinct_per_token() {
        // With tokens=1 the load total is exactly top_k and spread across
        // distinct experts.
        let rs = RoutingSkew::new(8, 8, 0.0);
        let mut rng = Rng::new(9);
        let loads = rs.sample_loads(1, &mut rng);
        assert!(loads.iter().all(|&l| l == 1));
    }

    #[test]
    fn activated_counts_bounded() {
        let rs = RoutingSkew::new(256, 8, 0.0);
        let mut rng = Rng::new(10);
        let a = rs.sample_activated(4, &mut rng);
        assert!((8..=32).contains(&a), "{a}");
        let a2 = rs.sample_activated(2048, &mut rng);
        assert!(a2 > 200, "{a2}");
    }
}
