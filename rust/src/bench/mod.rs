//! Minimal micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`run_suite`] with a closure registering cases on the [`Bencher`]:
//! warmup, then timed batches until the time budget is spent, reporting
//! mean / median / p95 per iteration and a relative std-dev quality
//! signal.  Output is stable, grep-able text that EXPERIMENTS.md §Perf
//! quotes directly, plus a machine-readable `BENCH_<name>.json`
//! ([`BenchSuite::to_json`]) — the per-PR perf trajectory ROADMAP asks
//! for.  The `bench` CLI subcommand emits the same schema with
//! fleet-sweep wall times attached.

use std::time::{Duration, Instant};

use crate::util::json::{obj, Json};
use crate::util::stats;

/// One benchmark report.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub rel_std: f64,
    /// Optional caller-provided throughput denominator (items/iter).
    pub items_per_iter: f64,
}

impl BenchReport {
    /// Throughput; 0 (never inf/NaN) for zero-duration or unmeasured
    /// batches.
    pub fn items_per_sec(&self) -> f64 {
        if self.mean_ns > 0.0 {
            self.items_per_iter * 1e9 / self.mean_ns
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("median_ns", Json::Num(self.median_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            ("rel_std", Json::Num(self.rel_std)),
            ("items_per_iter", Json::Num(self.items_per_iter)),
            ("items_per_sec", Json::Num(self.items_per_sec())),
        ])
    }
}

fn fmt_ns(ns: f64) -> String {
    if !ns.is_finite() {
        format!("{ns}")
    } else if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// The harness.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_batches: usize,
    reports: Vec<BenchReport>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Keep budgets modest: the box has one core and many benches.
        let quick = std::env::var("DWDP_BENCH_QUICK").is_ok();
        Bencher {
            warmup: Duration::from_millis(if quick { 20 } else { 150 }),
            budget: Duration::from_millis(if quick { 100 } else { 900 }),
            min_batches: 10,
            reports: Vec::new(),
        }
    }

    /// Run one case. `f` is invoked repeatedly; its return value is
    /// black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchReport {
        self.bench_n(name, 1.0, move || {
            std::hint::black_box(f());
        })
    }

    /// Run one case that processes `items` units per iteration (reports
    /// throughput too).
    pub fn bench_n<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &BenchReport {
        // Warmup + calibration: how many iters fit in ~1/20 of the budget?
        let w_end = Instant::now() + self.warmup;
        let mut warm_iters = 0u64;
        while Instant::now() < w_end {
            f();
            warm_iters += 1;
        }
        // Floor the estimate: a zero warmup budget (or a sub-ns case)
        // would otherwise divide the batch size by zero.
        let per_iter = (self.warmup.as_secs_f64() / warm_iters.max(1) as f64).max(1e-9);
        let batch =
            ((self.budget.as_secs_f64() / self.min_batches.max(1) as f64 / per_iter).ceil()
                as u64)
                .max(1);

        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let bench_end = Instant::now() + self.budget;
        while Instant::now() < bench_end || samples_ns.len() < self.min_batches {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(dt);
            total_iters += batch;
            if samples_ns.len() > 10_000 {
                break;
            }
        }
        let mean = stats::mean(&samples_ns);
        let report = BenchReport {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            median_ns: stats::median(&samples_ns),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            // std_dev of < 2 samples is meaningless (and its n-1 divisor
            // undefined); report a clean 0 instead.
            rel_std: if samples_ns.len() >= 2 && mean > 0.0 {
                stats::std_dev(&samples_ns) / mean
            } else {
                0.0
            },
            items_per_iter: items,
        };
        println!(
            "bench {:<44} mean {:>12}  median {:>12}  p95 {:>12}  ±{:>5.1}%{}",
            report.name,
            fmt_ns(report.mean_ns),
            fmt_ns(report.median_ns),
            fmt_ns(report.p95_ns),
            report.rel_std * 100.0,
            if items > 1.0 {
                format!("  ({:.2e} items/s)", report.items_per_sec())
            } else {
                String::new()
            }
        );
        self.reports.push(report);
        self.reports.last().unwrap()
    }

    pub fn reports(&self) -> &[BenchReport] {
        &self.reports
    }

    /// Print a closing summary (so `cargo bench` output has a footer).
    pub fn finish(&self) {
        println!("—— {} benchmarks complete ——", self.reports.len());
    }
}

/// One timed fleet-sweep point inside a [`BenchSuite`] (end-to-end wall
/// time, not a micro-bench: the interesting figure is simulated
/// requests routed per wall-clock second).
#[derive(Debug, Clone)]
pub struct SweepTiming {
    /// Sweep-point label, e.g. `"fleet/dwdp4 rate=40"`.
    pub label: String,
    /// Wall-clock seconds for the point.
    pub wall_seconds: f64,
    /// Requests the simulated fleet processed (offered load).
    pub requests: usize,
}

impl SweepTiming {
    /// Simulated requests per wall-clock second; 0 for a zero-duration
    /// point.
    pub fn requests_per_sec(&self) -> f64 {
        if self.wall_seconds > 0.0 {
            self.requests as f64 / self.wall_seconds
        } else {
            0.0
        }
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("requests", Json::Num(self.requests as f64)),
            ("requests_per_sec", Json::Num(self.requests_per_sec())),
        ])
    }
}

/// A named collection of bench reports and sweep timings — the unit the
/// perf trajectory records, one `BENCH_<name>.json` per suite.
#[derive(Debug, Clone, Default)]
pub struct BenchSuite {
    pub name: String,
    /// Total wall-clock seconds for the whole suite.
    pub wall_seconds: f64,
    pub reports: Vec<BenchReport>,
    pub sweep: Vec<SweepTiming>,
}

impl BenchSuite {
    pub fn new(name: &str) -> Self {
        BenchSuite { name: name.to_string(), ..Default::default() }
    }

    /// Record one timed sweep point.
    pub fn sweep_point(&mut self, label: &str, wall_seconds: f64, requests: usize) {
        self.sweep.push(SweepTiming { label: label.to_string(), wall_seconds, requests });
    }

    /// The `BENCH_<name>.json` schema (validated by CI's bench smoke):
    /// `{name, wall_seconds, benches: [{name, iters, mean_ns, median_ns,
    /// p95_ns, rel_std, items_per_iter, items_per_sec}], sweep: [{label,
    /// wall_seconds, requests, requests_per_sec}]}`.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("wall_seconds", Json::Num(self.wall_seconds)),
            ("benches", Json::Arr(self.reports.iter().map(|r| r.to_json()).collect())),
            ("sweep", Json::Arr(self.sweep.iter().map(|s| s.to_json()).collect())),
        ])
    }

    /// Write `BENCH_<name>.json` under `dir` and return the path.
    pub fn write(&self, dir: &str) -> std::io::Result<String> {
        let path = format!("{}/BENCH_{}.json", dir.trim_end_matches('/'), self.name);
        std::fs::write(&path, self.to_json().dump())?;
        Ok(path)
    }
}

/// Regression tolerance of the perf-trajectory gate, in percent
/// (`DWDP_BENCH_GATE_PCT` overrides; default 25).  Generous by design:
/// CI boxes are noisy, and the gate is after trajectory-scale
/// regressions (an accidentally quadratic router, a serialized core),
/// not single-digit jitter.
pub fn gate_threshold_pct() -> f64 {
    std::env::var("DWDP_BENCH_GATE_PCT")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .filter(|p| p.is_finite() && *p >= 0.0)
        .unwrap_or(25.0)
}

/// Outcome of gating a fresh suite against a committed baseline
/// ([`gate_against_baseline`]).
#[derive(Debug, Default)]
pub struct BenchGate {
    /// Informational lines: pending baseline, new unbaselined cases.
    pub notes: Vec<String>,
    /// Hard failures: regressions past the threshold, lost coverage, or
    /// a malformed baseline.
    pub regressions: Vec<String>,
}

impl BenchGate {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

fn json_entries<'a>(doc: &'a Json, list: &str, key: &str) -> Vec<(&'a str, &'a Json)> {
    doc.get(list)
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .filter_map(|e| e.get(key).as_str().map(|n| (n, e)))
        .collect()
}

/// The perf-trajectory gate: compare a fresh [`BenchSuite::to_json`]
/// document against a committed baseline of the same schema.
///
/// * Micro-bench cases regress when `median_ns` exceeds the baseline by
///   more than `max_regress_pct` percent (median, not mean — one noisy
///   outlier batch must not fail CI).
/// * Sweep points regress when `requests_per_sec` falls below the
///   baseline by more than the threshold.
/// * A case or sweep point present in the baseline but missing from the
///   fresh suite is a hard failure: deleting a bench silently resets the
///   trajectory.  New unbaselined cases are notes, not failures.
/// * A baseline whose `pending` field is non-null passes with a notice —
///   the bootstrap state before the first refresh commits real numbers.
pub fn gate_against_baseline(current: &Json, baseline: &Json, max_regress_pct: f64) -> BenchGate {
    let mut gate = BenchGate::default();
    if *baseline.get("pending") != Json::Null {
        gate.notes.push(
            "baseline is a pending marker: gate passes vacuously; \
             refresh it from this run's JSON to arm the trajectory"
                .to_string(),
        );
        return gate;
    }
    let checks: [(&str, &str, &str, bool); 2] = [
        // (list, id key, metric, higher-is-better)
        ("benches", "name", "median_ns", false),
        ("sweep", "label", "requests_per_sec", true),
    ];
    let mut any_base = false;
    for (list, id, metric, higher_better) in checks {
        let base = json_entries(baseline, list, id);
        let cur = json_entries(current, list, id);
        any_base |= !base.is_empty();
        for (name, b) in &base {
            let Some(base_v) = b.get(metric).as_f64().filter(|v| *v > 0.0) else {
                // A zero/absent baseline metric carries no signal.
                continue;
            };
            let Some(&(_, c)) = cur.iter().find(|(n, _)| n == name) else {
                gate.regressions.push(format!("{list}/{name}: missing from current suite"));
                continue;
            };
            let cur_v = c.get(metric).as_f64().unwrap_or(0.0);
            let ratio = if higher_better { base_v / cur_v.max(1e-12) } else { cur_v / base_v };
            let limit = 1.0 + max_regress_pct / 100.0;
            if ratio > limit {
                gate.regressions.push(format!(
                    "{list}/{name}: {metric} {cur_v:.1} vs baseline {base_v:.1} \
                     ({:+.1}% past the {max_regress_pct}% threshold)",
                    (ratio - 1.0) * 100.0
                ));
            }
        }
        for (name, _) in &cur {
            if !base.iter().any(|(n, _)| n == name) {
                gate.notes.push(format!("{list}/{name}: new case, no baseline yet"));
            }
        }
    }
    if !any_base {
        gate.regressions.push(
            "baseline has no bench cases and no sweep points (malformed, \
             and not marked pending)"
                .to_string(),
        );
    }
    gate
}

/// The shared `cargo bench` entry point: run `f`'s cases on a fresh
/// [`Bencher`], print the footer, and emit `BENCH_<name>.json` into the
/// working directory (the workspace root under `cargo bench`).  Returns
/// the suite so callers can post-process.
pub fn run_suite(name: &str, f: impl FnOnce(&mut Bencher)) -> BenchSuite {
    let t0 = Instant::now();
    let mut b = Bencher::new();
    f(&mut b);
    b.finish();
    let mut suite = BenchSuite::new(name);
    suite.wall_seconds = t0.elapsed().as_secs_f64();
    suite.reports = b.reports().to_vec();
    match suite.write(".") {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("bench: could not write BENCH_{name}.json: {e}"),
    }
    suite
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("DWDP_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("spin", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert_eq!(b.reports().len(), 1);
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("DWDP_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let v: Vec<u64> = (0..1000).collect();
        let r = b.bench_n("sum1k", 1000.0, || {
            std::hint::black_box(v.iter().sum::<u64>());
        });
        assert!(r.items_per_sec() > 1e6);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2.0e9).contains(" s"));
        assert_eq!(fmt_ns(f64::NAN), "NaN");
    }

    #[test]
    fn zero_duration_and_single_sample_edges_stay_finite() {
        let r = BenchReport {
            name: "degenerate".into(),
            iters: 1,
            mean_ns: 0.0,
            median_ns: 0.0,
            p95_ns: 0.0,
            rel_std: 0.0,
            items_per_iter: 1000.0,
        };
        assert_eq!(r.items_per_sec(), 0.0, "zero-duration must not be inf");
        let nan = BenchReport { mean_ns: f64::NAN, ..r };
        assert_eq!(nan.items_per_sec(), 0.0);

        // A zero warmup/budget bencher must neither hang (batch-size
        // division by zero) nor report a NaN rel_std from one sample.
        let mut b = Bencher::new();
        b.warmup = Duration::ZERO;
        b.budget = Duration::ZERO;
        b.min_batches = 1;
        let rep = b.bench("one-shot", || std::hint::black_box(1 + 1)).clone();
        assert!(rep.rel_std.is_finite());
        assert_eq!(rep.rel_std, 0.0);
        assert!(rep.iters >= 1);
    }

    #[test]
    fn suite_json_schema_round_trips() {
        std::env::set_var("DWDP_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        b.bench("noop", || std::hint::black_box(0u64));
        let mut suite = BenchSuite::new("unit");
        suite.wall_seconds = 0.25;
        suite.reports = b.reports().to_vec();
        suite.sweep_point("fleet/x rate=10", 0.5, 100);
        let parsed = crate::util::Json::parse(&suite.to_json().dump()).unwrap();
        assert_eq!(parsed.get("name").as_str(), Some("unit"));
        let benches = parsed.get("benches").as_arr().unwrap();
        assert_eq!(benches.len(), 1);
        for key in
            ["name", "iters", "mean_ns", "median_ns", "p95_ns", "rel_std", "items_per_sec"]
        {
            assert_ne!(benches[0].get(key), &crate::util::Json::Null, "missing {key}");
        }
        let sweep = parsed.get("sweep").as_arr().unwrap();
        assert!((sweep[0].get("requests_per_sec").as_f64().unwrap() - 200.0).abs() < 1e-9);

        let dir = std::env::temp_dir();
        let path = suite.write(dir.to_str().unwrap()).unwrap();
        assert!(path.ends_with("BENCH_unit.json"));
        assert!(crate::util::Json::parse(&std::fs::read_to_string(&path).unwrap()).is_ok());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn zero_wall_sweep_point_reports_zero_rate() {
        let s = SweepTiming { label: "x".into(), wall_seconds: 0.0, requests: 10 };
        assert_eq!(s.requests_per_sec(), 0.0);
    }

    fn suite_json(median_ns: f64, rps: f64) -> Json {
        Json::parse(&format!(
            r#"{{"name":"fleet_core","wall_seconds":1.0,
                "benches":[{{"name":"core","median_ns":{median_ns},"mean_ns":{median_ns}}}],
                "sweep":[{{"label":"fleet/a","requests_per_sec":{rps},"requests":48}}]}}"#
        ))
        .unwrap()
    }

    #[test]
    fn gate_passes_within_threshold_and_fails_past_it() {
        let base = suite_json(1000.0, 100.0);
        // 20% slower median, 20% lower throughput: inside a 25% gate.
        let ok = gate_against_baseline(&suite_json(1200.0, 80.0), &base, 25.0);
        assert!(ok.passed(), "{:?}", ok.regressions);
        // 30% slower median: out.
        let slow = gate_against_baseline(&suite_json(1300.0, 100.0), &base, 25.0);
        assert_eq!(slow.regressions.len(), 1, "{:?}", slow.regressions);
        assert!(slow.regressions[0].contains("benches/core"));
        // Throughput collapse fails on the sweep axis.
        let cold = gate_against_baseline(&suite_json(1000.0, 60.0), &base, 25.0);
        assert_eq!(cold.regressions.len(), 1, "{:?}", cold.regressions);
        assert!(cold.regressions[0].contains("sweep/fleet/a"));
        // An *improvement* never trips the gate.
        let fast = gate_against_baseline(&suite_json(10.0, 1e6), &base, 25.0);
        assert!(fast.passed());
    }

    #[test]
    fn gate_flags_lost_coverage_and_notes_new_cases() {
        let base = suite_json(1000.0, 100.0);
        let renamed = Json::parse(
            r#"{"name":"fleet_core","benches":[{"name":"other","median_ns":1.0}],
                "sweep":[{"label":"fleet/a","requests_per_sec":100.0}]}"#,
        )
        .unwrap();
        let g = gate_against_baseline(&renamed, &base, 25.0);
        assert!(!g.passed());
        assert!(g.regressions.iter().any(|r| r.contains("missing from current suite")));
        assert!(g.notes.iter().any(|n| n.contains("no baseline yet")));
    }

    #[test]
    fn gate_accepts_pending_marker_and_rejects_empty_baseline() {
        let cur = suite_json(1000.0, 100.0);
        let pending =
            Json::parse(r#"{"name":"fleet_core","pending":"first CI run refreshes"}"#).unwrap();
        let g = gate_against_baseline(&cur, &pending, 25.0);
        assert!(g.passed());
        assert!(g.notes[0].contains("pending"));

        let empty = Json::parse(r#"{"name":"fleet_core","benches":[],"sweep":[]}"#).unwrap();
        let g = gate_against_baseline(&cur, &empty, 25.0);
        assert!(!g.passed());
        assert!(g.regressions[0].contains("malformed"));
    }

    #[test]
    fn gate_threshold_env_override() {
        std::env::remove_var("DWDP_BENCH_GATE_PCT");
        assert_eq!(gate_threshold_pct(), 25.0);
        std::env::set_var("DWDP_BENCH_GATE_PCT", "40");
        assert_eq!(gate_threshold_pct(), 40.0);
        std::env::set_var("DWDP_BENCH_GATE_PCT", "not-a-number");
        assert_eq!(gate_threshold_pct(), 25.0);
        std::env::remove_var("DWDP_BENCH_GATE_PCT");
    }
}
