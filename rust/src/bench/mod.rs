//! Minimal micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bencher::bench`] for each case: warmup, then timed batches until the
//! time budget is spent, reporting mean / median / p95 per iteration and a
//! relative std-dev quality signal.  Output is stable, grep-able text that
//! EXPERIMENTS.md §Perf quotes directly.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark report.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub rel_std: f64,
    /// Optional caller-provided throughput denominator (items/iter).
    pub items_per_iter: f64,
}

impl BenchReport {
    pub fn items_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            self.items_per_iter * 1e9 / self.mean_ns
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// The harness.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_batches: usize,
    reports: Vec<BenchReport>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        // Keep budgets modest: the box has one core and many benches.
        let quick = std::env::var("DWDP_BENCH_QUICK").is_ok();
        Bencher {
            warmup: Duration::from_millis(if quick { 20 } else { 150 }),
            budget: Duration::from_millis(if quick { 100 } else { 900 }),
            min_batches: 10,
            reports: Vec::new(),
        }
    }

    /// Run one case. `f` is invoked repeatedly; its return value is
    /// black-boxed to keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchReport {
        self.bench_n(name, 1.0, move || {
            std::hint::black_box(f());
        })
    }

    /// Run one case that processes `items` units per iteration (reports
    /// throughput too).
    pub fn bench_n<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &BenchReport {
        // Warmup + calibration: how many iters fit in ~1/20 of the budget?
        let w_end = Instant::now() + self.warmup;
        let mut warm_iters = 0u64;
        while Instant::now() < w_end {
            f();
            warm_iters += 1;
        }
        let per_iter = self.warmup.as_secs_f64() / warm_iters.max(1) as f64;
        let batch =
            ((self.budget.as_secs_f64() / self.min_batches as f64 / per_iter).ceil() as u64)
                .max(1);

        let mut samples_ns: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let bench_end = Instant::now() + self.budget;
        while Instant::now() < bench_end || samples_ns.len() < self.min_batches {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(dt);
            total_iters += batch;
            if samples_ns.len() > 10_000 {
                break;
            }
        }
        let mean = stats::mean(&samples_ns);
        let report = BenchReport {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: mean,
            median_ns: stats::median(&samples_ns),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            rel_std: if mean > 0.0 { stats::std_dev(&samples_ns) / mean } else { 0.0 },
            items_per_iter: items,
        };
        println!(
            "bench {:<44} mean {:>12}  median {:>12}  p95 {:>12}  ±{:>5.1}%{}",
            report.name,
            fmt_ns(report.mean_ns),
            fmt_ns(report.median_ns),
            fmt_ns(report.p95_ns),
            report.rel_std * 100.0,
            if items > 1.0 {
                format!("  ({:.2e} items/s)", report.items_per_sec())
            } else {
                String::new()
            }
        );
        self.reports.push(report);
        self.reports.last().unwrap()
    }

    pub fn reports(&self) -> &[BenchReport] {
        &self.reports
    }

    /// Print a closing summary (so `cargo bench` output has a footer).
    pub fn finish(&self) {
        println!("—— {} benchmarks complete ——", self.reports.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        std::env::set_var("DWDP_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let mut acc = 0u64;
        let r = b.bench("spin", || {
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.iters > 0);
        assert_eq!(b.reports().len(), 1);
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("DWDP_BENCH_QUICK", "1");
        let mut b = Bencher::new();
        let v: Vec<u64> = (0..1000).collect();
        let r = b.bench_n("sum1k", 1000.0, || {
            std::hint::black_box(v.iter().sum::<u64>());
        });
        assert!(r.items_per_sec() > 1e6);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2.0e9).contains(" s"));
    }
}
