//! Experiment regenerators — one entry point per table/figure in the
//! paper's evaluation (DESIGN.md maps each to its modules).
//!
//! Every function returns a [`Table`] whose rows mirror the paper's
//! artifact.  The regenerators are thin callers of the unified serving API:
//! each builds a [`crate::serving::Scenario`], runs it through a
//! [`crate::serving::ServingStack`], and formats the resulting
//! [`crate::serving::RunReport`].  They are registered (id → runner) in
//! [`crate::serving::registry`], which the CLI dispatches through.
//! Calibration constants that tie the simulator to the paper's measured
//! scale are centralized in [`calib`] and documented in EXPERIMENTS.md.

pub mod context;
pub mod e2e;
pub mod fleet;
pub mod power;

use crate::config::ParallelMode;
use crate::contention::{contention_distribution, monte_carlo_contention};
use crate::roofline::{crossover_isl, fig3_sweep};
use crate::serving::{Scenario, ScenarioSpec};
use crate::util::table::{pct, speedup, us, Table};

/// Calibration presets (see EXPERIMENTS.md §Calibration for derivations).
pub mod calib {
    use super::*;
    use crate::serving::Scenario;

    /// The paper's context-server deployment evidently fetches ~320 MB of
    /// remote expert weights per layer per rank (Table 1: 429 µs of P2P at
    /// ~750 GB/s), i.e. ~13 of 192 remote experts — strong EPLB locality +
    /// on-demand fetch.  This fraction reproduces that operating point.
    pub const TABLE1_PREFETCH_FRACTION: f64 = 0.07;

    /// Fig. 3's batch-1 crossover at ~16K ISL implies an effective
    /// batch-1 pull bandwidth near 300 GB/s (single in-flight pull chain,
    /// no batching of transfers).
    pub const FIG3_CE_BW: f64 = 300.0e9;

    /// Calibrated context-phase scenario (Table 1/3/4 base): the shared
    /// starting point every context experiment then tweaks per sweep.
    pub fn context_scenario(mode: ParallelMode, group: usize) -> Scenario {
        Scenario::context()
            .mode(mode)
            .group(group)
            .prefetch_fraction(TABLE1_PREFETCH_FRACTION)
            .seed(7)
            .requests(n_requests())
    }

    /// Calibrated disaggregated scenario (§5.3 base): SemiAnalysis-style
    /// workload, DWDP/DEP applied to the context servers only.
    pub fn e2e_scenario(mode: ParallelMode) -> Scenario {
        Scenario::disagg()
            .mode(mode)
            .group(4)
            .isl(8192)
            .ratio(0.8)
            .osl(1024)
            .prefetch_fraction(TABLE1_PREFETCH_FRACTION)
            .seed(7)
    }

    /// Requests per rank for context experiments (quick mode for tests).
    pub fn n_requests() -> usize {
        if std::env::var("DWDP_QUICK").is_ok() {
            1
        } else {
            2
        }
    }
}

/// E2 — Figure 3: roofline compute/prefetch and DEP/DWDP ratios vs ISL.
pub fn fig3() -> Table {
    // Batch-1 roofline: full remote fetch (no on-demand calibration), pull
    // bandwidth calibrated to the paper's measured batch-1 crossover.
    let spec = Scenario::context()
        .mode(ParallelMode::Dwdp)
        .group(4)
        .ce_bw(calib::FIG3_CE_BW)
        .build()
        .expect("fig3 scenario");
    let (hw, model, serving) = (&spec.hw, &spec.model, &spec.serving);
    let isls = [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072, 262144];
    let pts = fig3_sweep(hw, model, serving, &isls);
    let mut t = Table::new(&[
        "ISL",
        "T_compute (µs)",
        "T_prefetch (µs)",
        "T_all2all (µs)",
        "compute/prefetch",
        "T_DEP/T_DWDP",
    ])
    .with_title("Figure 3 — roofline analysis, DeepSeek-R1 context phase, DWDP4 vs DEP4, bs=1");
    for p in &pts {
        t.row(vec![
            p.isl.to_string(),
            us(p.t_compute_us),
            us(p.t_prefetch_us),
            us(p.t_all2all_us),
            format!("{:.3}", p.compute_prefetch_ratio),
            format!("{:.3}", p.dep_dwdp_ratio),
        ]);
    }
    if let Some(x) = crossover_isl(hw, model, serving, 1024, 262144) {
        t.row(vec![
            format!("crossover ≈ {x}"),
            "-".into(),
            "-".into(),
            "-".into(),
            "1.000".into(),
            "-".into(),
        ]);
    }
    t
}

/// The fig3 spec for the registry's static linter — the roofline sweep
/// reuses this single calibrated scenario across all ISLs.
pub fn fig3_registry_specs() -> Result<Vec<ScenarioSpec>, String> {
    Ok(vec![Scenario::context()
        .mode(ParallelMode::Dwdp)
        .group(4)
        .ce_bw(calib::FIG3_CE_BW)
        .build()?])
}

/// E4 — Table 2: contention probabilities under the random model, with a
/// Monte-Carlo cross-check column.
pub fn table2() -> Table {
    let mut t = Table::new(&[
        "Config", "C = 1", "C = 2", "C = 3", "C = 4", "C = 5", "C = 6", "C = 7", "C = 8",
        "max |MC-analytic|",
    ])
    .with_title("Table 2 — Pr[C = c] (%) under the random asynchronous model");
    for n in [3usize, 4, 6, 8, 12, 16] {
        let d = contention_distribution(n);
        let mc = monte_carlo_contention(n, 100_000, 42);
        let max_err = d
            .iter()
            .zip(&mc)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        let mut row = vec![format!("DWDP{n}")];
        for c in 0..8 {
            row.push(d.get(c).map(|&p| pct(p)).unwrap_or_else(|| "-".into()));
        }
        row.push(format!("{max_err:.4}"));
        t.row(row);
    }
    t
}

/// Convenience: a ratio formatted like the paper's speedup tables.
pub(crate) fn ratio(a: f64, b: f64) -> String {
    if b == 0.0 {
        "-".into()
    } else {
        speedup(a / b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_table_has_crossover_row() {
        let t = fig3();
        let s = t.render();
        assert!(s.contains("crossover"));
        assert!(t.n_rows() >= 9);
    }

    #[test]
    fn table2_matches_paper_spot_values() {
        let s = table2().render();
        // DWDP3: 50 / 50; DWDP4: 44.44 / 44.44 / 11.11
        assert!(s.contains("DWDP3"));
        assert!(s.contains("44.44"));
        assert!(s.contains("11.11"));
        assert!(s.contains("DWDP16"));
    }

    #[test]
    fn ratio_formats() {
        assert_eq!(ratio(1.1, 1.0), "1.10");
        assert_eq!(ratio(1.0, 0.0), "-");
    }
}
