//! End-to-end disaggregated-serving experiments: Figure 5 (Pareto
//! frontier), Table 5 (speedups per TPS/user range), Table 6 (TTFT).
//!
//! Setup mirrors §5.3: SemiAnalysis-style workload (ISL ∈ [6.4K, 8K],
//! OSL 1K), generation-server configuration fixed, DWDP applied only to
//! the context servers, improved points found primarily by reducing the
//! number of context groups.  Every point is one
//! [`crate::serving::Scenario`] run through the [`ServingStack`] at
//! analytic fidelity (the sweep is hundreds of points; the DES backend
//! prices identical scenarios when higher fidelity is wanted).

use super::calib;
use crate::config::ParallelMode;
use crate::serving::{Fidelity, RunReport, Scenario, ScenarioSpec, ServingStack};
use crate::util::table::{f, Table};

fn n_reqs() -> usize {
    if std::env::var("DWDP_QUICK").is_ok() {
        400
    } else {
        1600
    }
}

/// Sweep a frontier for one mode: vary context groups × arrival rate ×
/// generation pool size.  Memoized per mode (fig5/table5/table6 share it).
pub fn sweep(mode: ParallelMode) -> Vec<RunReport> {
    static CACHE: std::sync::OnceLock<
        std::sync::Mutex<std::collections::BTreeMap<&'static str, Vec<RunReport>>>,
    > = std::sync::OnceLock::new();
    let cache = CACHE.get_or_init(Default::default);
    if let Some(hit) = cache.lock().unwrap().get(mode.name()) {
        return hit.clone();
    }
    let pts = sweep_uncached(mode);
    cache.lock().unwrap().insert(mode.name(), pts.clone());
    pts
}

/// The frontier sweep's full scenario grid for one mode — the single
/// source of truth for both [`sweep`] and the static linter's registry
/// specs (fig5/table5/table6 enumerate through here, so the linter can
/// never drift from what actually runs).
pub fn sweep_scenarios(mode: ParallelMode) -> Vec<Scenario> {
    let mut scns = Vec::new();
    for &n_ctx in &[1usize, 2, 3, 4, 6] {
        for &n_gen in &[16usize, 32] {
            for &rate in &[2.0f64, 5.0, 9.0, 11.0, 12.5, 14.0, 15.0, 16.0] {
                scns.push(
                    calib::e2e_scenario(mode)
                        .ctx_groups(n_ctx)
                        .gen_gpus(n_gen)
                        .rate(rate)
                        .requests(n_reqs()),
                );
            }
        }
    }
    scns
}

/// The swept specs for the registry's static linter.
pub fn registry_specs(mode: ParallelMode) -> Result<Vec<ScenarioSpec>, String> {
    sweep_scenarios(mode).into_iter().map(|s| s.build()).collect()
}

fn sweep_uncached(mode: ParallelMode) -> Vec<RunReport> {
    sweep_scenarios(mode)
        .into_iter()
        .map(|scn| {
            let spec = scn.build().expect("e2e scenario");
            ServingStack::new(spec, Fidelity::Analytic).run().expect("analytic backend")
        })
        .collect()
}

/// Keep only Pareto-optimal points (maximize both TPS/user and TPS/GPU).
pub fn pareto(points: &[RunReport]) -> Vec<RunReport> {
    let mut keep: Vec<RunReport> = Vec::new();
    for p in points {
        if points.iter().any(|q| {
            q.tps_per_user > p.tps_per_user * 1.001 && q.tps_per_gpu > p.tps_per_gpu * 1.001
        }) {
            continue;
        }
        keep.push(p.clone());
    }
    keep.sort_by(|a, b| a.tps_per_user.total_cmp(&b.tps_per_user));
    keep
}

/// E12 — Figure 5: the two Pareto frontiers.
pub fn fig5() -> Table {
    let dep = pareto(&sweep(ParallelMode::Dep));
    let dwdp = pareto(&sweep(ParallelMode::Dwdp));
    let mut t = Table::new(&[
        "frontier", "TPS/user", "output TPS/GPU", "ctx groups", "gen GPUs", "TTFT (ms)",
    ])
    .with_title("Figure 5 — end-to-end Pareto frontier, baseline (DEP ctx) vs DWDP ctx");
    for (name, pts) in [("baseline", &dep), ("DWDP", &dwdp)] {
        for p in pts {
            t.row(vec![
                name.into(),
                f(p.tps_per_user, 1),
                f(p.tps_per_gpu, 1),
                p.n_ctx_groups.to_string(),
                p.n_gen_gpus.to_string(),
                f(p.median_ttft * 1e3, 0),
            ]);
        }
    }
    t
}

/// Match each baseline frontier point with the DWDP point of closest
/// TPS/user; aggregate speedups per TPS/user bin.
fn matched_bins() -> Vec<(String, f64, f64, f64, f64)> {
    let dep = pareto(&sweep(ParallelMode::Dep));
    let dwdp = pareto(&sweep(ParallelMode::Dwdp));
    let bins: [(f64, f64); 5] =
        [(20.0, 30.0), (40.0, 50.0), (60.0, 70.0), (80.0, 90.0), (170.0, 180.0)];
    let mut rows = Vec::new();
    for (lo, hi) in bins {
        let base: Vec<&RunReport> = dep
            .iter()
            .filter(|p| p.tps_per_user >= lo && p.tps_per_user < hi)
            .collect();
        if base.is_empty() {
            continue;
        }
        let mut su_user = Vec::new();
        let mut su_gpu = Vec::new();
        let mut ttft_base = Vec::new();
        let mut ttft_dwdp = Vec::new();
        for b in &base {
            // closest-TPS/user DWDP point
            let m = dwdp.iter().min_by(|x, y| {
                (x.tps_per_user - b.tps_per_user)
                    .abs()
                    .total_cmp(&(y.tps_per_user - b.tps_per_user).abs())
            });
            if let Some(m) = m {
                su_user.push(m.tps_per_user / b.tps_per_user);
                su_gpu.push(m.tps_per_gpu / b.tps_per_gpu);
                ttft_base.push(b.median_ttft * 1e3);
                ttft_dwdp.push(m.median_ttft * 1e3);
            }
        }
        let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        rows.push((
            format!("{}-{}", lo as u32, hi as u32),
            avg(&su_user),
            avg(&su_gpu),
            avg(&ttft_base),
            avg(&ttft_dwdp),
        ));
    }
    rows
}

/// E13 — Table 5: average speedups per TPS/user range.
pub fn table5() -> Table {
    let mut t = Table::new(&[
        "TPS/user Range",
        "Avg. DWDP TPS/user Speedup",
        "Avg. DWDP TPS/GPU Speedup",
    ])
    .with_title("Table 5 — end-to-end performance summary per TPS/user range");
    for (range, su, sg, _, _) in matched_bins() {
        t.row(vec![range, format!("{su:.2}"), format!("{sg:.2}")]);
    }
    t
}

/// E14 — Table 6: median TTFT comparison per range.
pub fn table6() -> Table {
    let mut t = Table::new(&[
        "TPS/user Range",
        "TPS/GPU Speedup",
        "Baseline TTFT (ms)",
        "DWDP TTFT (ms)",
    ])
    .with_title("Table 6 — median TTFT comparison (incl. queueing)");
    for (range, _, sg, tb, tw) in matched_bins() {
        t.row(vec![range, format!("{sg:.2}"), f(tb, 0), f(tw, 0)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() {
        std::env::set_var("DWDP_QUICK", "1");
    }

    fn mk(u: f64, g: f64) -> RunReport {
        RunReport {
            tps_per_user: u,
            tps_per_gpu: g,
            median_ttft: 0.1,
            n_requests: 1,
            ..RunReport::default()
        }
    }

    #[test]
    fn pareto_filters_dominated_points() {
        let pts = vec![mk(10.0, 10.0), mk(20.0, 20.0), mk(5.0, 5.0)];
        let keep = pareto(&pts);
        assert_eq!(keep.len(), 1);
        assert_eq!(keep[0].tps_per_user, 20.0);
    }

    #[test]
    fn sweep_produces_frontier_points() {
        quick();
        let pts = sweep(ParallelMode::Dwdp);
        assert!(pts.len() >= 40);
        let front = pareto(&pts);
        assert!(!front.is_empty());
        // Frontier is sorted and non-dominated.
        for w in front.windows(2) {
            assert!(w[1].tps_per_user >= w[0].tps_per_user);
        }
    }

    #[test]
    fn fig5_dwdp_improves_tps_gpu_somewhere() {
        quick();
        let dep = pareto(&sweep(ParallelMode::Dep));
        let dwdp = pareto(&sweep(ParallelMode::Dwdp));
        // At a comparable TPS/user, DWDP should reach >= baseline TPS/GPU
        // for at least one matched pair (the paper's headline effect).
        let mut improved = false;
        for b in &dep {
            if let Some(m) = dwdp.iter().min_by(|x, y| {
                (x.tps_per_user - b.tps_per_user)
                    .abs()
                    .total_cmp(&(y.tps_per_user - b.tps_per_user).abs())
            }) {
                if m.tps_per_gpu > b.tps_per_gpu {
                    improved = true;
                    break;
                }
            }
        }
        assert!(improved, "DWDP frontier never beats baseline");
    }
}
