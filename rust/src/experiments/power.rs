//! Appendix-A experiments: Table 7 / Figure 8 (overlap patterns vs DVFS
//! frequency) and the Figure 7 pattern traces.
//!
//! Unlike the context/e2e regenerators these do not describe a serving
//! workload — they drive the simulator with hand-built synthetic programs
//! — so they sit below the `Scenario` abstraction and are reached through
//! [`crate::serving::registry`] (id `table7`) like every other scenario.
//!
//! Reproduces the three scheduling configurations with synthetic programs
//! on a single simulated GPU:
//!
//! 1. **Intermittent Compute** — attention modules separated by large
//!    sleeps, no communication: maximum power headroom.
//! 2. **Long-Duration Overlap (with gaps)** — each attention module
//!    overlaps one long CE transfer, gaps preserved.
//! 3. **Short-Duration Overlap** — tightly scheduled attention modules
//!    with small CE transfers, the real-DWDP-like pattern.

use crate::config::HardwareConfig;
use crate::model::{Category, OpKind};
use crate::sim::{ComputeStep, Simulation, Step};
use crate::trace::TraceSink;
use crate::util::table::{f, Table};

/// One attention "module" (16K-context scale ≈ 2 ms of SM time).
fn attn_module() -> Step {
    Step::Compute(ComputeStep {
        name: "attention_module",
        category: Category::Attention,
        kind: OpKind::FlashAttention,
        nominal: 2.0e-3,
    })
}

const N_MODULES: usize = 24;

pub struct PatternResult {
    pub name: &'static str,
    pub kernel_time: f64,
    pub mean_freq: f64,
    pub trace: TraceSink,
}

fn run_pattern(name: &'static str, program: Vec<Step>, hw: &HardwareConfig) -> PatternResult {
    let mut sim = Simulation::new(hw, 1, 11);
    sim.enable_trace();
    sim.set_program(0, program);
    let res = sim.run();
    PatternResult {
        name,
        kernel_time: res.ranks[0].breakdown.get(Category::Attention) / N_MODULES as f64,
        mean_freq: res.ranks[0].mean_freq,
        trace: res.trace,
    }
}

/// Run the three patterns; returns results ordered as the paper's Table 7.
pub fn run_patterns() -> Vec<PatternResult> {
    let mut hw = HardwareConfig::gb200();
    hw.link_jitter_prob = 0.0;
    let gap = 8.0e-3; // sleep >> power_tau: full recovery

    // 1. Intermittent: sleep, attention, sleep, ...
    let mut p1 = Vec::new();
    for _ in 0..N_MODULES {
        p1.push(Step::Sleep { secs: gap });
        p1.push(attn_module());
    }

    // 2. Long-duration overlap: one long CE task spanning each module,
    //    gaps preserved.
    let mut p2 = Vec::new();
    for _ in 0..N_MODULES {
        p2.push(Step::Sleep { secs: gap });
        p2.push(Step::CeLocalTask { bytes: 2.4e-3 * hw.ce_bw });
        p2.push(attn_module());
    }

    // 3. Short-duration overlap: tight schedule, small transfers.
    let mut p3 = Vec::new();
    for _ in 0..N_MODULES {
        p3.push(Step::CeLocalTask { bytes: 2.0e-3 * hw.ce_bw });
        p3.push(attn_module());
    }

    vec![
        run_pattern("Intermittent Compute", p1, &hw),
        run_pattern("Long-Duration Overlap", p2, &hw),
        run_pattern("Short-Duration Overlap", p3, &hw),
    ]
}

/// E15 — Table 7 / Figure 8: normalized kernel time and GPU frequency.
pub fn table7() -> Table {
    let rs = run_patterns();
    let base_time = rs[0].kernel_time;
    let base_freq = rs[0].mean_freq;
    let mut t = Table::new(&["Pattern", "Normalized Kernel Time", "Normalized GPU Frequency"])
        .with_title("Table 7 / Fig. 8 — attention module under three communication-overlap patterns");
    for r in &rs {
        t.row(vec![
            r.name.to_string(),
            f(r.kernel_time / base_time, 3),
            f(r.mean_freq / base_freq, 3),
        ]);
    }
    t
}

/// E16 — Figure 7: merged trace of the three patterns (stacked tracks).
pub fn fig7_trace() -> TraceSink {
    let rs = run_patterns();
    let mut merged = TraceSink::enabled();
    for r in rs {
        for s in r.trace.spans {
            merged.record(
                &format!("{}::{}", r.name, s.track),
                &s.name,
                &s.cat,
                s.start,
                s.dur,
            );
        }
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn short_overlap_slowest_lowest_freq() {
        let rs = run_patterns();
        assert_eq!(rs.len(), 3);
        // Paper Table 7: time 1.000 < 1.049 < 1.226; freq 1.0 > 0.963 > 0.798.
        assert!(rs[1].kernel_time > rs[0].kernel_time * 1.005, "long-overlap should slow");
        assert!(rs[2].kernel_time > rs[1].kernel_time, "short-overlap slowest");
        assert!(rs[1].mean_freq < rs[0].mean_freq);
        assert!(rs[2].mean_freq < rs[1].mean_freq);
    }

    #[test]
    fn kernel_time_tracks_frequency() {
        // Fig. 8's correlation: time_i/time_0 ≈ freq_0/freq_i within 10%.
        let rs = run_patterns();
        for r in &rs[1..] {
            let t_ratio = r.kernel_time / rs[0].kernel_time;
            let f_ratio = rs[0].mean_freq / r.mean_freq;
            assert!(
                (t_ratio / f_ratio - 1.0).abs() < 0.12,
                "{}: time {t_ratio:.3} vs 1/freq {f_ratio:.3}",
                r.name
            );
        }
    }

    #[test]
    fn table7_renders_three_rows() {
        let t = table7();
        assert_eq!(t.n_rows(), 3);
        assert!(t.render().contains("Short-Duration Overlap"));
    }

    #[test]
    fn fig7_trace_has_all_patterns() {
        let tr = fig7_trace();
        let tracks: std::collections::BTreeSet<&str> =
            tr.spans.iter().map(|s| s.track.as_str()).collect();
        assert!(tracks.iter().any(|t| t.starts_with("Intermittent")));
        assert!(tracks.iter().any(|t| t.starts_with("Short-Duration")));
    }
}
