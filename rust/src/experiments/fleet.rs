//! Fleet-scale regenerators: the cluster frontier, burst robustness, and
//! trace-replay scenarios (`fleet_frontier`, `fleet_burst`, `fleet_trace`
//! in the registry).
//!
//! These go beyond the paper's single-deployment §5.3 sweep: they stress
//! DWDP's no-sync independence claim at cluster granularity, under the
//! dynamic workloads where parallelization comparisons are known to flip
//! (Shift Parallelism, 2509.16495) and with the fleet-level workload
//! metrics that make capacity claims actionable (Kundu et al.,
//! 2407.14645).  All three run at analytic fidelity through the parallel
//! [`crate::fleet::sweep`] driver.

use crate::config::ParallelMode;
use crate::fleet::{available_threads, run_sweep, ClusterPolicy, SweepPoint};
use crate::serving::{Fidelity, RunReport, Scenario};
use crate::util::table::{f, Table};
use crate::workload::{ArrivalProcess, IslDist, OpenLoopGen, OslDist, WorkloadTrace};

use super::calib;

fn quick() -> bool {
    std::env::var("DWDP_QUICK").is_ok()
}

/// Requests offered per fleet point.
fn n_requests() -> usize {
    if quick() {
        24
    } else {
        96
    }
}

/// Calibrated fleet base: SemiAnalysis-style prompts on DWDP/DEP groups of
/// 4 with the routing-skew imbalance knob on — the cross-rank imbalance
/// DWDP is designed to tolerate.
pub fn fleet_scenario(mode: ParallelMode, n_groups: usize) -> Scenario {
    Scenario::fleet()
        .mode(mode)
        .group(4)
        .groups(n_groups)
        .isl(8192)
        .ratio(0.8)
        .osl_window(256, 1024)
        .prefetch_fraction(calib::TABLE1_PREFETCH_FRACTION)
        .routing_skew(1.0)
        .requests(n_requests())
        .seed(7)
}

/// A bursty recording all trace-replay rows share: generated once from the
/// Gamma-burst process, round-tripped through the canonical JSON encoding
/// so replay rows exercise the full write→read path.
fn recorded_trace(rate: f64) -> WorkloadTrace {
    let mut gen = OpenLoopGen::new(
        ArrivalProcess::GammaBurst { rate, cv2: 8.0 },
        IslDist::RatioWindow { isl: 8192, ratio: 0.8 },
        OslDist::Uniform { lo: 256, hi: 1024 },
        7,
    );
    let trace = WorkloadTrace::record(&mut gen, n_requests());
    WorkloadTrace::parse(&trace.dump()).expect("canonical trace round-trips")
}

fn report_row(label: &str, r: &RunReport) -> Vec<String> {
    vec![
        label.to_string(),
        r.n_requests.to_string(),
        r.shed.to_string(),
        f(r.p50_ttft * 1e3, 0),
        f(r.p95_ttft * 1e3, 0),
        f(r.p99_ttft * 1e3, 0),
        f(r.p99_tpot * 1e3, 1),
        f(r.tps_per_gpu, 1),
        f(r.goodput * 100.0, 1),
    ]
}

const ROW_HEADER: [&str; 9] = [
    "scenario",
    "served",
    "shed",
    "p50 TTFT (ms)",
    "p95 TTFT (ms)",
    "p99 TTFT (ms)",
    "p99 TPOT (ms)",
    "TPS/GPU",
    "goodput (%)",
];

/// One table row per sweep point; a point that errored gets a "failed"
/// stub padded to the header width.
fn rows_into(t: &mut Table, points: &[SweepPoint], reports: &[Result<RunReport, String>]) {
    for (p, r) in points.iter().zip(reports) {
        match r {
            Ok(r) => {
                t.row(report_row(&p.label, r));
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(ROW_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
}

/// `fleet_frontier` — DWDP vs DEP over a 4-group cluster under Poisson,
/// bursty Gamma, and trace-replay arrivals, from one parallel sweep.  The
/// sweep is run once single-threaded and once across all cores; the final
/// row records whether the two passes were bit-identical (the determinism
/// contract of `fleet::sweep`).
pub fn fleet_frontier() -> Table {
    let rate = 6.0;
    let trace = recorded_trace(rate);
    let arrivals: Vec<(&str, ArrivalProcess)> = vec![
        ("poisson", ArrivalProcess::Poisson { rate }),
        ("burst", ArrivalProcess::GammaBurst { rate, cv2: 8.0 }),
        ("trace", ArrivalProcess::Replay { trace }),
    ];
    let mut points = Vec::new();
    for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
        for (name, process) in &arrivals {
            let spec = fleet_scenario(mode, 4)
                .arrival(process.clone())
                .build()
                .expect("fleet_frontier scenario");
            points.push(SweepPoint::new(
                &format!("{}4 x4 {name}", mode.name()),
                spec,
                Fidelity::Analytic,
            ));
        }
    }
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel
        .iter()
        .zip(&serial)
        .all(|(a, b)| match (a, b) {
            (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
            (Err(a), Err(b)) => a == b,
            _ => false,
        });
    let mut t = Table::new(&ROW_HEADER)
        .with_title("Fleet frontier: DWDP vs DEP, 4 groups, three arrival processes");
    rows_into(&mut t, &points, &parallel);
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(ROW_HEADER.len(), "-".into());
    t.row(row);
    t
}

/// `fleet_burst` — hold the mean rate fixed and crank burstiness (CV² of
/// the Gamma inter-arrivals): DEP's lockstep groups absorb bursts worse
/// than DWDP's independent ranks, and the gap widens in the tail.
pub fn fleet_burst() -> Table {
    let rate = 6.0;
    let mut points = Vec::new();
    for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
        for cv2 in [1.0, 4.0, 16.0] {
            let spec = fleet_scenario(mode, 4)
                .arrival(ArrivalProcess::GammaBurst { rate, cv2 })
                .build()
                .expect("fleet_burst scenario");
            points.push(SweepPoint::new(
                &format!("{}4 x4 cv2={cv2}", mode.name()),
                spec,
                Fidelity::Analytic,
            ));
        }
    }
    let reports = run_sweep(&points, available_threads());
    let mut t = Table::new(&ROW_HEADER)
        .with_title("Fleet burst robustness: Gamma arrivals, rising CV² at fixed mean rate");
    rows_into(&mut t, &points, &reports);
    t
}

/// `fleet_trace` — record a bursty workload, write it to
/// `fleet_trace.json`, read it back (byte-identical), and replay the same
/// offered load under all three cluster policies: with identical arrivals
/// the policy differences (tail latency vs shedding) are causal, not
/// sampling noise.
pub fn fleet_trace() -> Table {
    let trace = recorded_trace(10.0);
    // Exercise the on-disk round trip; fall back to the in-memory trace
    // when the temp directory is not writable.  Per-process filename so
    // concurrent runs (tests vs CLI, parallel CI jobs) cannot interleave.
    let path = std::env::temp_dir().join(format!("dwdp_fleet_trace_{}.json", std::process::id()));
    let path = path.to_string_lossy().to_string();
    let trace = match trace.write_file(&path) {
        Ok(()) => {
            let read = WorkloadTrace::read_file(&path).expect("just-written trace reads back");
            assert_eq!(read.dump(), trace.dump(), "trace round trip must be byte-identical");
            eprintln!("workload trace: {path}");
            read
        }
        Err(_) => trace,
    };
    let policies = [
        ClusterPolicy::RoundRobin,
        ClusterPolicy::LeastOutstandingTokens,
        ClusterPolicy::SloAdmission { max_wait: 1.0 },
    ];
    let mut points = Vec::new();
    for policy in policies {
        let spec = fleet_scenario(ParallelMode::Dwdp, 4)
            .arrival(ArrivalProcess::Replay { trace: trace.clone() })
            .cluster_policy(policy)
            .build()
            .expect("fleet_trace scenario");
        points.push(SweepPoint::new(
            &format!("DWDP4 x4 {}", policy.name()),
            spec,
            Fidelity::Analytic,
        ));
    }
    let reports = run_sweep(&points, available_threads());
    let mut t = Table::new(&ROW_HEADER)
        .with_title("Trace replay: one recorded burst workload, three cluster policies");
    rows_into(&mut t, &points, &reports);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontier_covers_modes_and_arrivals_and_is_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_frontier();
        // 2 modes x 3 arrivals + the determinism row.
        assert_eq!(t.n_rows(), 7);
        let text = t.render();
        for needle in ["DWDP4", "DEP4", "poisson", "burst", "trace", "bit-identical"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn burst_table_has_all_cv2_rows() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_burst();
        assert_eq!(t.n_rows(), 6);
        assert!(t.render().contains("cv2=16"));
    }

    #[test]
    fn trace_table_covers_all_policies() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_trace();
        assert_eq!(t.n_rows(), 3);
        let text = t.render();
        for needle in ["round-robin", "least-outstanding", "slo-admission"] {
            assert!(text.contains(needle), "missing {needle}");
        }
    }
}
