//! Fleet-scale regenerators: the cluster frontier, burst robustness,
//! trace-replay, re-placement, failure-injection, closed-loop session,
//! and unified-HBM-budget scenarios (`fleet_frontier`, `fleet_burst`,
//! `fleet_trace`, `replacement_skew`, `fleet_churn`, `sessions`,
//! `memory_pressure` in the registry).
//!
//! These go beyond the paper's single-deployment §5.3 sweep: they stress
//! DWDP's no-sync independence claim at cluster granularity, under the
//! dynamic workloads where parallelization comparisons are known to flip
//! (Shift Parallelism, 2509.16495) and with the fleet-level workload
//! metrics that make capacity claims actionable (Kundu et al.,
//! 2407.14645).  All three run at analytic fidelity through the parallel
//! [`crate::fleet::sweep`] driver.

use crate::config::ParallelMode;
use crate::fleet::{available_threads, rack_axis, run_sweep, ClusterPolicy, SweepPoint};
use crate::serving::{Fidelity, RunReport, Scenario, ScenarioSpec};
use crate::util::table::{f, Table};
use crate::workload::{ArrivalProcess, IslDist, OpenLoopGen, OslDist, WorkloadTrace};

use super::calib;

fn quick() -> bool {
    std::env::var("DWDP_QUICK").is_ok()
}

/// Requests offered per fleet point.
fn n_requests() -> usize {
    if quick() {
        24
    } else {
        96
    }
}

/// Calibrated fleet base: SemiAnalysis-style prompts on DWDP/DEP groups of
/// 4 with the routing-skew imbalance knob on — the cross-rank imbalance
/// DWDP is designed to tolerate.
pub fn fleet_scenario(mode: ParallelMode, n_groups: usize) -> Scenario {
    Scenario::fleet()
        .mode(mode)
        .group(4)
        .groups(n_groups)
        .isl(8192)
        .ratio(0.8)
        .osl_window(256, 1024)
        .prefetch_fraction(calib::TABLE1_PREFETCH_FRACTION)
        .routing_skew(1.0)
        .requests(n_requests())
        .seed(7)
}

/// Scenario for the re-placement sweep: redundant expert placement at full
/// on-demand prefetch — the regime where *which* experts are local moves
/// DWDP's per-layer prefetch bound, so the placement knob is causal.
pub fn replacement_scenario(
    mode: ParallelMode,
    skew: f64,
    local_experts: usize,
    interval: usize,
) -> Scenario {
    Scenario::fleet()
        .mode(mode)
        .group(4)
        .groups(2)
        .isl(8192)
        .ratio(0.8)
        .osl_window(256, 1024)
        .local_experts(local_experts)
        .prefetch_fraction(1.0)
        .routing_skew(skew)
        .replacement_interval(interval)
        .rate(6.0)
        .requests(n_requests())
        .seed(7)
}

/// Scenario for the churn sweep: the calibrated fleet base under Poisson
/// arrivals with failure injection and re-queueing on.  MTBF 0 disables
/// failures (the "mtbf=inf" baseline rows).
pub fn churn_scenario(mode: ParallelMode, mtbf: f64, mttr: f64) -> Scenario {
    fleet_scenario(mode, 4)
        .rate(4.0)
        .mtbf(mtbf)
        .mttr(mttr)
        .requeue_on_failure(true)
}

/// Scenario for the multirack sweep: the calibrated DWDP fleet base over
/// a tiered topology — 4 groups spread across `racks` racks behind a
/// 25 GB/s inter-rack spine (NVLink runs ~36x faster), under the given
/// cluster policy.  `racks = 1` is the flat baseline.
pub fn multirack_scenario(policy: ClusterPolicy, racks: usize) -> Scenario {
    fleet_scenario(ParallelMode::Dwdp, 4)
        .cluster_policy(policy)
        .racks(racks)
        .inter_rack_gbps(25.0)
        .inter_rack_latency(3e-6)
}

/// A bursty recording all trace-replay rows share: generated once from the
/// Gamma-burst process, round-tripped through the canonical JSON encoding
/// so replay rows exercise the full write→read path.
fn recorded_trace(rate: f64) -> WorkloadTrace {
    let mut gen = OpenLoopGen::new(
        ArrivalProcess::GammaBurst { rate, cv2: 8.0 },
        IslDist::RatioWindow { isl: 8192, ratio: 0.8 },
        OslDist::Uniform { lo: 256, hi: 1024 },
        7,
    );
    let trace = WorkloadTrace::record(&mut gen, n_requests());
    WorkloadTrace::parse(&trace.dump()).expect("canonical trace round-trips")
}

fn report_row(label: &str, r: &RunReport) -> Vec<String> {
    vec![
        label.to_string(),
        r.n_requests.to_string(),
        r.shed.to_string(),
        f(r.p50_ttft * 1e3, 0),
        f(r.p95_ttft * 1e3, 0),
        f(r.p99_ttft * 1e3, 0),
        f(r.p99_tpot * 1e3, 1),
        f(r.tps_per_gpu, 1),
        f(r.goodput * 100.0, 1),
    ]
}

const ROW_HEADER: [&str; 9] = [
    "scenario",
    "served",
    "shed",
    "p50 TTFT (ms)",
    "p95 TTFT (ms)",
    "p99 TTFT (ms)",
    "p99 TPOT (ms)",
    "TPS/GPU",
    "goodput (%)",
];

/// One table row per sweep point; a point that errored gets a "failed"
/// stub padded to the header width.
fn rows_into(t: &mut Table, points: &[SweepPoint], reports: &[Result<RunReport, String>]) {
    for (p, r) in points.iter().zip(reports) {
        match r {
            Ok(r) => {
                t.row(report_row(&p.label, r));
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(ROW_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
}

/// `fleet_frontier` — DWDP vs DEP over a 4-group cluster under Poisson,
/// bursty Gamma, and trace-replay arrivals, from one parallel sweep.  The
/// sweep is run once single-threaded and once across all cores; the final
/// row records whether the two passes were bit-identical (the determinism
/// contract of `fleet::sweep`).
pub fn fleet_frontier() -> Table {
    let rate = 6.0;
    let trace = recorded_trace(rate);
    let arrivals: Vec<(&str, ArrivalProcess)> = vec![
        ("poisson", ArrivalProcess::Poisson { rate }),
        ("burst", ArrivalProcess::GammaBurst { rate, cv2: 8.0 }),
        ("trace", ArrivalProcess::Replay { trace }),
    ];
    let mut points = Vec::new();
    for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
        for (name, process) in &arrivals {
            let spec = fleet_scenario(mode, 4)
                .arrival(process.clone())
                .build()
                .expect("fleet_frontier scenario");
            points.push(SweepPoint::new(
                &format!("{}4 x4 {name}", mode.name()),
                spec,
                Fidelity::Analytic,
            ));
        }
    }
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel
        .iter()
        .zip(&serial)
        .all(|(a, b)| match (a, b) {
            (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
            (Err(a), Err(b)) => a == b,
            _ => false,
        });
    let mut t = Table::new(&ROW_HEADER)
        .with_title("Fleet frontier: DWDP vs DEP, 4 groups, three arrival processes");
    rows_into(&mut t, &points, &parallel);
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(ROW_HEADER.len(), "-".into());
    t.row(row);
    t
}

/// `fleet_burst` — hold the mean rate fixed and crank burstiness (CV² of
/// the Gamma inter-arrivals): DEP's lockstep groups absorb bursts worse
/// than DWDP's independent ranks, and the gap widens in the tail.
pub fn fleet_burst() -> Table {
    let rate = 6.0;
    let mut points = Vec::new();
    for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
        for cv2 in [1.0, 4.0, 16.0] {
            let spec = fleet_scenario(mode, 4)
                .arrival(ArrivalProcess::GammaBurst { rate, cv2 })
                .build()
                .expect("fleet_burst scenario");
            points.push(SweepPoint::new(
                &format!("{}4 x4 cv2={cv2}", mode.name()),
                spec,
                Fidelity::Analytic,
            ));
        }
    }
    let reports = run_sweep(&points, available_threads());
    let mut t = Table::new(&ROW_HEADER)
        .with_title("Fleet burst robustness: Gamma arrivals, rising CV² at fixed mean rate");
    rows_into(&mut t, &points, &reports);
    t
}

/// `fleet_trace` — record a bursty workload, write it to
/// `fleet_trace.json`, read it back (byte-identical), and replay the same
/// offered load under all three cluster policies: with identical arrivals
/// the policy differences (tail latency vs shedding) are causal, not
/// sampling noise.
pub fn fleet_trace() -> Table {
    let trace = recorded_trace(10.0);
    // Exercise the on-disk round trip; fall back to the in-memory trace
    // when the temp directory is not writable.  Per-process filename so
    // concurrent runs (tests vs CLI, parallel CI jobs) cannot interleave.
    let path = std::env::temp_dir().join(format!("dwdp_fleet_trace_{}.json", std::process::id()));
    let path = path.to_string_lossy().to_string();
    let trace = match trace.write_file(&path) {
        Ok(()) => {
            let read = WorkloadTrace::read_file(&path).expect("just-written trace reads back");
            assert_eq!(read.dump(), trace.dump(), "trace round trip must be byte-identical");
            eprintln!("workload trace: {path}");
            read
        }
        Err(_) => trace,
    };
    let policies = [
        ClusterPolicy::RoundRobin,
        ClusterPolicy::LeastOutstandingTokens,
        ClusterPolicy::SloAdmission { max_wait: 1.0 },
    ];
    let mut points = Vec::new();
    for policy in policies {
        let spec = fleet_scenario(ParallelMode::Dwdp, 4)
            .arrival(ArrivalProcess::Replay { trace: trace.clone() })
            .cluster_policy(policy)
            .build()
            .expect("fleet_trace scenario");
        points.push(SweepPoint::new(
            &format!("DWDP4 x4 {}", policy.name()),
            spec,
            Fidelity::Analytic,
        ));
    }
    let reports = run_sweep(&points, available_threads());
    let mut t = Table::new(&ROW_HEADER)
        .with_title("Trace replay: one recorded burst workload, three cluster policies");
    rows_into(&mut t, &points, &reports);
    t
}

/// Pull a named backend extra off a report ("-" when absent).
fn extra<'a>(r: &'a RunReport, key: &str) -> &'a str {
    r.extras
        .iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v.as_str())
        .unwrap_or("-")
}

const REPLACEMENT_HEADER: [&str; 8] = [
    "scenario",
    "served",
    "p50 TTFT (ms)",
    "p99 TTFT (ms)",
    "TPS/GPU",
    "remote fetch (GB)",
    "migrated (GB)",
    "re-placements",
];

/// `replacement_skew` — the online expert re-placement sweep: DWDP with a
/// frozen `ExpertPlacement::balanced` vs the EPLB-style re-placement loop
/// vs DEP, across routing skew × re-placement interval × placement
/// redundancy.  At skew 0 the re-placement knob is an exact no-op; at
/// skew >= 1 with redundant placement the dynamic rows fetch strictly
/// fewer remote bytes and serve more TPS/GPU than static (asserted by the
/// fleet test-suite).  The final row re-checks sweep determinism across
/// thread counts with re-placement enabled.
pub fn replacement_skew() -> Table {
    let mut points = Vec::new();
    for &skew in &[0.0, 1.0, 1.5] {
        for &local in &[64usize, 96] {
            for (tag, interval) in [("static", 0usize), ("eplb/8", 8)] {
                let spec = replacement_scenario(ParallelMode::Dwdp, skew, local, interval)
                    .build()
                    .expect("replacement_skew scenario");
                points.push(SweepPoint::new(
                    &format!("DWDP4 x2 skew={skew} local={local} {tag}"),
                    spec,
                    Fidelity::Analytic,
                ));
            }
        }
        let dep = replacement_scenario(ParallelMode::Dep, skew, 64, 0)
            .build()
            .expect("replacement_skew DEP baseline");
        points.push(SweepPoint::new(
            &format!("DEP4 x2 skew={skew}"),
            dep,
            Fidelity::Analytic,
        ));
    }
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel.iter().zip(&serial).all(|(a, b)| match (a, b) {
        (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
        (Err(a), Err(b)) => a == b,
        _ => false,
    });
    let mut t = Table::new(&REPLACEMENT_HEADER).with_title(
        "Online expert re-placement: DWDP static vs dynamic vs DEP, skew x interval x redundancy",
    );
    for (p, r) in points.iter().zip(&parallel) {
        match r {
            Ok(r) => {
                t.row(vec![
                    p.label.clone(),
                    r.n_requests.to_string(),
                    f(r.p50_ttft * 1e3, 0),
                    f(r.p99_ttft * 1e3, 0),
                    f(r.tps_per_gpu, 1),
                    extra(r, "remote fetch (GB)").to_string(),
                    extra(r, "migrated (GB)").to_string(),
                    extra(r, "re-placements").to_string(),
                ]);
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(REPLACEMENT_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(REPLACEMENT_HEADER.len(), "-".into());
    t.row(row);
    t
}

const CHURN_HEADER: [&str; 9] = [
    "scenario",
    "offered",
    "served",
    "failed",
    "requeued",
    "availability (%)",
    "p99 TTFT (ms)",
    "goodput (%)",
    "churn goodput (%)",
];

/// `fleet_churn` — failure injection: DWDP vs the DEP-coupled mode over a
/// 4-group cluster at equal MTBF/MTTR.  Per-group failure streams are
/// identical across the two modes (same seeds), so the gap is causal: a
/// DWDP failure takes out one group while the router re-steers around it;
/// a DEP failure stalls every group sharing the dead group's expert
/// shards for the repair + warm-up.  The mtbf=inf rows pin the zero-delta
/// contract (failure injection off is bit-identical to the legacy path),
/// and the final row re-checks sweep determinism across thread counts
/// with churn enabled.
pub fn fleet_churn() -> Table {
    let mttr = 2.0;
    let mut points = Vec::new();
    for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
        for (tag, mtbf) in [("mtbf=inf", 0.0), ("mtbf=20s", 20.0), ("mtbf=5s", 5.0)] {
            let spec = churn_scenario(mode, mtbf, mttr).build().expect("fleet_churn scenario");
            points.push(SweepPoint::new(
                &format!("{}4 x4 {tag}", mode.name()),
                spec,
                Fidelity::Analytic,
            ));
        }
    }
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel.iter().zip(&serial).all(|(a, b)| match (a, b) {
        (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
        (Err(a), Err(b)) => a == b,
        _ => false,
    });
    let mut t = Table::new(&CHURN_HEADER).with_title(
        "Fleet churn: failure injection at equal MTBF/MTTR, DWDP independence vs DEP lockstep",
    );
    for (p, r) in points.iter().zip(&parallel) {
        match r {
            Ok(r) => {
                t.row(vec![
                    p.label.clone(),
                    r.offered.to_string(),
                    r.n_requests.to_string(),
                    r.failed.to_string(),
                    r.requeued.to_string(),
                    f(r.availability * 100.0, 1),
                    f(r.p99_ttft * 1e3, 0),
                    f(r.goodput * 100.0, 1),
                    extra(r, "goodput under churn (%)").to_string(),
                ]);
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(CHURN_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(CHURN_HEADER.len(), "-".into());
    t.row(row);
    t
}

const MULTIRACK_HEADER: [&str; 9] = [
    "scenario",
    "served",
    "p50 TTFT (ms)",
    "p99 TTFT (ms)",
    "TPS/GPU",
    "x-rack req",
    "x-rack GB",
    "availability (%)",
    "goodput (%)",
];

/// `multirack` — the rack-tiered topology sweep: the flat single-domain
/// fleet vs the same groups spread over 2 and 4 racks, under rack-blind
/// least-outstanding routing and the rack-local-first policy that prices
/// the inter-rack spill.  With identical arrivals per rack count the
/// cross-rack traffic gap is causal: rack-local-first strictly reduces
/// `cross_rack_bytes` at equal offered load (asserted in this module's
/// tests — the PR acceptance criterion).  The correlated-failure rows
/// flip `rack_blast_radius` at equal MTBF/MTTR: one blast downs a whole
/// rack and recovery re-pulls expert shards over the spine, so
/// availability drops in rack-sized steps.  The final row re-checks sweep
/// determinism across thread counts with the topology enabled.
pub fn multirack() -> Table {
    let mut points = Vec::new();
    // The rack-count axis, rack-blind vs rack-local at every tier count.
    let blind = multirack_scenario(ClusterPolicy::LeastOutstandingTokens, 1);
    points.extend(
        rack_axis(&blind, &[1, 2, 4], Fidelity::Analytic).expect("multirack blind axis"),
    );
    let local = multirack_scenario(ClusterPolicy::RackLocalFirst, 1);
    points.extend(
        rack_axis(&local, &[2, 4], Fidelity::Analytic).expect("multirack rack-local axis"),
    );
    // Correlated failures: same MTBF/MTTR, blast radius of one group vs
    // one rack.
    for (tag, blast) in [("per-group failures", false), ("rack blast", true)] {
        let spec = multirack_scenario(ClusterPolicy::RackLocalFirst, 2)
            .mtbf(15.0)
            .mttr(2.0)
            .requeue_on_failure(true)
            .rack_blast_radius(blast)
            .build()
            .expect("multirack churn scenario");
        points.push(SweepPoint::new(
            &format!("{} · {tag}", spec.label),
            spec,
            Fidelity::Analytic,
        ));
    }
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel.iter().zip(&serial).all(|(a, b)| match (a, b) {
        (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
        (Err(a), Err(b)) => a == b,
        _ => false,
    });
    let mut t = Table::new(&MULTIRACK_HEADER).with_title(
        "Multirack: flat vs rack-tiered topology, rack-blind vs rack-local-first routing",
    );
    for (p, r) in points.iter().zip(&parallel) {
        match r {
            Ok(r) => {
                t.row(vec![
                    p.label.clone(),
                    r.n_requests.to_string(),
                    f(r.p50_ttft * 1e3, 0),
                    f(r.p99_ttft * 1e3, 0),
                    f(r.tps_per_gpu, 1),
                    r.cross_rack_requests.to_string(),
                    f(r.cross_rack_bytes / 1e9, 3),
                    f(r.availability * 100.0, 1),
                    f(r.goodput * 100.0, 1),
                ]);
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(MULTIRACK_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(MULTIRACK_HEADER.len(), "-".into());
    t.row(row);
    t
}

/// Scenario for the closed-loop session sweep: the calibrated DWDP fleet
/// base with users cycling request → think → follow-up for up to 4 turns.
/// Follow-up prompts carry the whole prior context, so the KV-prefix cache
/// (and the policy's willingness to route back to it) is what separates
/// the rows.
pub fn sessions_scenario(policy: ClusterPolicy, think: f64) -> Scenario {
    fleet_scenario(ParallelMode::Dwdp, 4)
        .rate(4.0)
        .sessions(true)
        .session_turns(4)
        .think_time(think)
        .cluster_policy(policy)
}

/// Scenario for the unified-HBM-budget pressure sweep: the closed-loop
/// session base under `hbm_budget`, so resident expert redundancy
/// (`local_experts`), the KV budget (derived from the device when
/// `kv_gb == 0`, an explicit per-group override otherwise), and context
/// length all draw from one per-group memory hierarchy.  The host-offload
/// tier is on: preempted/evicted prefixes are re-fetched over
/// `LinkTier::Host` instead of re-prefilled.
pub fn memory_pressure_scenario(local: usize, kv_gb: f64, isl: usize) -> Scenario {
    sessions_scenario(ClusterPolicy::PrefixAffinity, 0.5)
        .isl(isl)
        .local_experts(local)
        .hbm_budget(true)
        .kv_capacity_gb(kv_gb)
        .host_offload(true)
}

const SESSIONS_HEADER: [&str; 9] = [
    "scenario",
    "offered",
    "served",
    "follow-ups",
    "hit rate (%)",
    "saved tokens",
    "follow-up TTFT (ms)",
    "turn p95 (s)",
    "goodput (%)",
];

/// `sessions` — the closed-loop session sweep: sticky prefix-affinity vs
/// rack-blind least-outstanding vs SLO admission, at short and long think
/// times, plus one churn row (failures invalidate the downed group's
/// resident caches) and the thread-determinism row.  With identical
/// session plans per column the hit-rate and follow-up-TTFT gaps are
/// causal: only the router's stickiness differs.
pub fn sessions() -> Table {
    let policies = [
        ClusterPolicy::PrefixAffinity,
        ClusterPolicy::LeastOutstandingTokens,
        ClusterPolicy::SloAdmission { max_wait: 1.0 },
    ];
    let mut points = Vec::new();
    for policy in policies {
        for think in [0.5, 4.0] {
            let spec = sessions_scenario(policy, think)
                .build()
                .expect("sessions scenario");
            points.push(SweepPoint::new(
                &format!("DWDP4 x4 {} think={think}s", policy.name()),
                spec,
                Fidelity::Analytic,
            ));
        }
    }
    let churn = sessions_scenario(ClusterPolicy::PrefixAffinity, 0.5)
        .mtbf(15.0)
        .mttr(2.0)
        .requeue_on_failure(true)
        .slo(1e4, 1e4)
        .build()
        .expect("sessions churn scenario");
    points.push(SweepPoint::new(
        "DWDP4 x4 prefix-affinity think=0.5s churn",
        churn,
        Fidelity::Analytic,
    ));
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel.iter().zip(&serial).all(|(a, b)| match (a, b) {
        (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
        (Err(a), Err(b)) => a == b,
        _ => false,
    });
    let mut t = Table::new(&SESSIONS_HEADER).with_title(
        "Closed-loop sessions: KV-prefix affinity vs rack-blind routing, hit rate x think time x churn",
    );
    for (p, r) in points.iter().zip(&parallel) {
        match r {
            Ok(r) => {
                let hit_rate = if r.follow_ups > 0 {
                    r.prefix_hits as f64 / r.follow_ups as f64 * 100.0
                } else {
                    0.0
                };
                t.row(vec![
                    p.label.clone(),
                    r.offered.to_string(),
                    r.n_requests.to_string(),
                    r.follow_ups.to_string(),
                    f(hit_rate, 1),
                    r.prefix_tokens_saved.to_string(),
                    f(r.follow_up_mean_ttft * 1e3, 0),
                    f(r.p95_turn, 2),
                    f(r.goodput * 100.0, 1),
                ]);
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(SESSIONS_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(SESSIONS_HEADER.len(), "-".into());
    t.row(row);
    t
}

const MEMORY_HEADER: [&str; 10] = [
    "scenario",
    "served",
    "hit rate (%)",
    "p99 TTFT (ms)",
    "TPS/GPU",
    "hbm weight (GB/rank)",
    "hbm kv peak (GB/rank)",
    "deferred",
    "host fetches",
    "goodput (%)",
];

/// `memory_pressure` — the unified-HBM-budget sweep: expert redundancy ×
/// KV budget × context length over the closed-loop session base, all
/// drawing from one per-group memory hierarchy.  The redundancy axis runs
/// the derived budget (what the device leaves after weights + headroom);
/// the budget axis pins redundancy and shrinks an explicit per-group
/// override; the context axis doubles the ISL at mid redundancy.  Rows
/// where the budget never binds print "-" for the memory extras (the
/// zero-delta contract: an unbounded budget is byte-identical to the
/// pre-budget fleet).  The final row re-checks sweep determinism across
/// thread counts with the budget enabled.
pub fn memory_pressure() -> Table {
    let mut points = Vec::new();
    for &local in &[64usize, 96, 128] {
        let spec = memory_pressure_scenario(local, 0.0, 8192)
            .build()
            .expect("memory_pressure redundancy axis");
        points.push(SweepPoint::new(
            &format!("DWDP4 x4 local={local} kv=derived"),
            spec,
            Fidelity::Analytic,
        ));
    }
    for &kv in &[2.0, 0.5] {
        let spec = memory_pressure_scenario(64, kv, 8192)
            .build()
            .expect("memory_pressure budget axis");
        points.push(SweepPoint::new(
            &format!("DWDP4 x4 local=64 kv={kv}GB"),
            spec,
            Fidelity::Analytic,
        ));
    }
    let spec = memory_pressure_scenario(96, 0.0, 16384)
        .build()
        .expect("memory_pressure context axis");
    points.push(SweepPoint::new(
        "DWDP4 x4 local=96 kv=derived isl=16k",
        spec,
        Fidelity::Analytic,
    ));
    let parallel = run_sweep(&points, available_threads());
    let serial = run_sweep(&points, 1);
    let bit_identical = parallel.iter().zip(&serial).all(|(a, b)| match (a, b) {
        (Ok(a), Ok(b)) => a.to_json().dump() == b.to_json().dump(),
        (Err(a), Err(b)) => a == b,
        _ => false,
    });
    let mut t = Table::new(&MEMORY_HEADER).with_title(
        "Memory pressure: one HBM budget across redundancy x KV residency x context length",
    );
    for (p, r) in points.iter().zip(&parallel) {
        match r {
            Ok(r) => {
                let hit_rate = if r.follow_ups > 0 {
                    r.prefix_hits as f64 / r.follow_ups as f64 * 100.0
                } else {
                    0.0
                };
                t.row(vec![
                    p.label.clone(),
                    r.n_requests.to_string(),
                    f(hit_rate, 1),
                    f(r.p99_ttft * 1e3, 0),
                    f(r.tps_per_gpu, 1),
                    extra(r, "hbm weight (GB/rank)").to_string(),
                    extra(r, "hbm kv peak (GB/rank)").to_string(),
                    extra(r, "deferred admissions").to_string(),
                    extra(r, "host fetches").to_string(),
                    f(r.goodput * 100.0, 1),
                ]);
            }
            Err(e) => {
                let mut row = vec![format!("{} (failed: {e})", p.label)];
                row.resize(MEMORY_HEADER.len(), "-".into());
                t.row(row);
            }
        }
    }
    let mut row = vec![
        "sweep determinism (1 thread vs all cores)".to_string(),
        if bit_identical { "bit-identical" } else { "MISMATCH" }.to_string(),
    ];
    row.resize(MEMORY_HEADER.len(), "-".into());
    t.row(row);
    t
}

/// The swept specs for the registry's static linter — one arm per fleet
/// regenerator, mirroring the exact scenario grids above (same builders,
/// same axes) so `dwdp-repro lint` verifies what actually runs.  The
/// trace-replay rows use the in-memory recording (no temp-file round
/// trip: the linter only needs the compiled programs, not the I/O path).
pub fn registry_specs(id: &str) -> Result<Vec<ScenarioSpec>, String> {
    let mut scns: Vec<Scenario> = Vec::new();
    match id {
        "fleet_frontier" => {
            let rate = 6.0;
            let trace = recorded_trace(rate);
            let arrivals: Vec<ArrivalProcess> = vec![
                ArrivalProcess::Poisson { rate },
                ArrivalProcess::GammaBurst { rate, cv2: 8.0 },
                ArrivalProcess::Replay { trace },
            ];
            for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
                for process in &arrivals {
                    scns.push(fleet_scenario(mode, 4).arrival(process.clone()));
                }
            }
        }
        "fleet_burst" => {
            for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
                for cv2 in [1.0, 4.0, 16.0] {
                    scns.push(
                        fleet_scenario(mode, 4)
                            .arrival(ArrivalProcess::GammaBurst { rate: 6.0, cv2 }),
                    );
                }
            }
        }
        "fleet_trace" => {
            let trace = recorded_trace(10.0);
            for policy in [
                ClusterPolicy::RoundRobin,
                ClusterPolicy::LeastOutstandingTokens,
                ClusterPolicy::SloAdmission { max_wait: 1.0 },
            ] {
                scns.push(
                    fleet_scenario(ParallelMode::Dwdp, 4)
                        .arrival(ArrivalProcess::Replay { trace: trace.clone() })
                        .cluster_policy(policy),
                );
            }
        }
        "replacement_skew" => {
            for &skew in &[0.0, 1.0, 1.5] {
                for &local in &[64usize, 96] {
                    for interval in [0usize, 8] {
                        scns.push(replacement_scenario(
                            ParallelMode::Dwdp,
                            skew,
                            local,
                            interval,
                        ));
                    }
                }
                scns.push(replacement_scenario(ParallelMode::Dep, skew, 64, 0));
            }
        }
        "fleet_churn" => {
            for mode in [ParallelMode::Dep, ParallelMode::Dwdp] {
                for mtbf in [0.0, 20.0, 5.0] {
                    scns.push(churn_scenario(mode, mtbf, 2.0));
                }
            }
        }
        "multirack" => {
            let mut specs = Vec::new();
            let blind = multirack_scenario(ClusterPolicy::LeastOutstandingTokens, 1);
            specs.extend(
                rack_axis(&blind, &[1, 2, 4], Fidelity::Analytic)?
                    .into_iter()
                    .map(|p| p.spec),
            );
            let local = multirack_scenario(ClusterPolicy::RackLocalFirst, 1);
            specs.extend(
                rack_axis(&local, &[2, 4], Fidelity::Analytic)?.into_iter().map(|p| p.spec),
            );
            for blast in [false, true] {
                scns.push(
                    multirack_scenario(ClusterPolicy::RackLocalFirst, 2)
                        .mtbf(15.0)
                        .mttr(2.0)
                        .requeue_on_failure(true)
                        .rack_blast_radius(blast),
                );
            }
            for scn in scns {
                specs.push(scn.build()?);
            }
            return Ok(specs);
        }
        "sessions" => {
            for policy in [
                ClusterPolicy::PrefixAffinity,
                ClusterPolicy::LeastOutstandingTokens,
                ClusterPolicy::SloAdmission { max_wait: 1.0 },
            ] {
                for think in [0.5, 4.0] {
                    scns.push(sessions_scenario(policy, think));
                }
            }
            scns.push(
                sessions_scenario(ClusterPolicy::PrefixAffinity, 0.5)
                    .mtbf(15.0)
                    .mttr(2.0)
                    .requeue_on_failure(true)
                    .slo(1e4, 1e4),
            );
        }
        "memory_pressure" => {
            for &local in &[64usize, 96, 128] {
                scns.push(memory_pressure_scenario(local, 0.0, 8192));
            }
            for &kv in &[2.0, 0.5] {
                scns.push(memory_pressure_scenario(64, kv, 8192));
            }
            scns.push(memory_pressure_scenario(96, 0.0, 16384));
        }
        other => return Err(format!("no fleet spec enumerator for '{other}'")),
    }
    scns.into_iter().map(|s| s.build()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::simulate_analytic;

    #[test]
    fn frontier_covers_modes_and_arrivals_and_is_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_frontier();
        // 2 modes x 3 arrivals + the determinism row.
        assert_eq!(t.n_rows(), 7);
        let text = t.render();
        for needle in ["DWDP4", "DEP4", "poisson", "burst", "trace", "bit-identical"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn burst_table_has_all_cv2_rows() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_burst();
        assert_eq!(t.n_rows(), 6);
        assert!(t.render().contains("cv2=16"));
    }

    #[test]
    fn trace_table_covers_all_policies() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_trace();
        assert_eq!(t.n_rows(), 3);
        let text = t.render();
        for needle in ["round-robin", "least-outstanding", "slo-admission"] {
            assert!(text.contains(needle), "missing {needle}");
        }
    }

    #[test]
    fn replacement_table_covers_the_sweep_and_stays_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = replacement_skew();
        // 3 skews x (2 redundancies x 2 intervals + 1 DEP) + determinism.
        assert_eq!(t.n_rows(), 16);
        let text = t.render();
        for needle in ["static", "eplb/8", "DEP4", "local=96", "bit-identical"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn churn_table_covers_modes_and_mtbf_and_stays_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = fleet_churn();
        // 2 modes x 3 MTBF levels + the determinism row.
        assert_eq!(t.n_rows(), 7);
        let text = t.render();
        for needle in ["DWDP4", "DEP4", "mtbf=inf", "mtbf=5s", "bit-identical"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    /// The PR-4 acceptance criterion: at equal MTBF/MTTR the `fleet_churn`
    /// scenario's DWDP goodput degrades strictly more gracefully than the
    /// DEP-coupled mode, and with failures disabled (mtbf 0 or infinity)
    /// the outcome is identical to the pre-churn path.
    #[test]
    fn dwdp_goodput_degrades_more_gracefully_than_dep() {
        let run = |mode, mtbf| {
            // Pin the load regardless of DWDP_QUICK; an effectively
            // unbounded SLO makes churn goodput measure completed-vs-
            // offered, so the comparison is about the failure model, not
            // latency calibration.
            let spec = churn_scenario(mode, mtbf, 2.0)
                .requests(64)
                .slo(1e4, 1e4)
                .build()
                .unwrap();
            simulate_analytic(&spec).unwrap()
        };
        let dwdp = run(ParallelMode::Dwdp, 5.0);
        let dep = run(ParallelMode::Dep, 5.0);
        assert_eq!(dwdp.offered, dep.offered, "identical offered load");
        assert!(dep.failed > 0, "lockstep churn must lose requests");
        assert!(
            dwdp.goodput_under_churn() > dep.goodput_under_churn(),
            "DWDP churn goodput {} must degrade more gracefully than DEP {}",
            dwdp.goodput_under_churn(),
            dep.goodput_under_churn()
        );
        // Zero delta with failures disabled, for both disabling spellings.
        for mode in [ParallelMode::Dwdp, ParallelMode::Dep] {
            let base = simulate_analytic(
                &fleet_scenario(mode, 4).rate(4.0).requests(64).build().unwrap(),
            )
            .unwrap();
            for mtbf in [0.0, f64::INFINITY] {
                let off = simulate_analytic(
                    &churn_scenario(mode, mtbf, 2.0).requests(64).build().unwrap(),
                )
                .unwrap();
                assert_eq!(off.failed, 0);
                assert_eq!(off.metrics.median_ttft(), base.metrics.median_ttft());
                assert_eq!(off.span, base.span);
                assert_eq!(off.admitted, base.admitted);
            }
        }
    }

    #[test]
    fn multirack_table_covers_the_axis_and_stays_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = multirack();
        // 3 rack-blind tiers + 2 rack-local tiers + 2 churn rows +
        // the determinism row.
        assert_eq!(t.n_rows(), 8);
        let text = t.render();
        for needle in [
            "over 2 racks",
            "over 4 racks",
            "rack-local",
            "least-outstanding",
            "rack blast",
            "per-group failures",
            "bit-identical",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    /// The PR-5 acceptance criterion: a 1-rack tiered topology reproduces
    /// the flat fleet bit-for-bit (same `RunReport::to_json()`), and at
    /// 2 racks with a finite inter-rack link rack-local-first routing
    /// strictly reduces cross-rack bytes vs rack-blind least-outstanding
    /// at equal offered load.
    #[test]
    fn rack_local_first_beats_rack_blind_routing_cross_rack() {
        use crate::serving::ServingStack;
        // Pin the load regardless of DWDP_QUICK.
        let run = |policy, racks| {
            let spec = multirack_scenario(policy, racks).requests(64).build().unwrap();
            ServingStack::new(spec, Fidelity::Analytic).run().unwrap()
        };
        // Zero delta: the flat fleet and a 1-rack tiered config emit the
        // same JSON fingerprint, float for float.
        let flat = run(ClusterPolicy::LeastOutstandingTokens, 1);
        let one_rack = {
            let spec = fleet_scenario(ParallelMode::Dwdp, 4)
                .cluster_policy(ClusterPolicy::LeastOutstandingTokens)
                .requests(64)
                .build()
                .unwrap();
            ServingStack::new(spec, Fidelity::Analytic).run().unwrap()
        };
        assert_eq!(flat.to_json().dump(), one_rack.to_json().dump());
        // The tiered gap: rack-blind ships bytes over the spine that
        // rack-local-first keeps home.
        let blind = run(ClusterPolicy::LeastOutstandingTokens, 2);
        let local = run(ClusterPolicy::RackLocalFirst, 2);
        assert_eq!(blind.offered, local.offered, "identical offered load");
        assert!(blind.cross_rack_requests > 0, "rack-blind routing must spill");
        assert!(
            local.cross_rack_bytes < blind.cross_rack_bytes,
            "rack-local {} must beat rack-blind {}",
            local.cross_rack_bytes,
            blind.cross_rack_bytes
        );
    }

    /// The PR-3 acceptance criterion: at `routing_skew >= 1` with
    /// redundant placement, dynamic re-placement strictly reduces
    /// remote-fetch bytes and improves TPS/GPU over the frozen
    /// `ExpertPlacement::balanced`; at skew 0 the knob is an exact no-op.
    #[test]
    fn dynamic_replacement_beats_static_at_skew_one() {
        let run = |skew: f64, interval: usize| {
            let spec = replacement_scenario(ParallelMode::Dwdp, skew, 96, interval)
                .requests(64) // pin the load regardless of DWDP_QUICK
                .build()
                .unwrap();
            simulate_analytic(&spec).unwrap()
        };
        let n_gpus = 2 * 4;
        let stat = run(1.0, 0);
        let dynamic = run(1.0, 8);
        assert!(dynamic.replacements > 0);
        assert!(
            dynamic.remote_fetch_bytes < stat.remote_fetch_bytes,
            "remote fetch: dynamic {} vs static {}",
            dynamic.remote_fetch_bytes,
            stat.remote_fetch_bytes
        );
        let stat_tps = stat.metrics.output_tps_per_gpu(n_gpus, stat.span);
        let dyn_tps = dynamic.metrics.output_tps_per_gpu(n_gpus, dynamic.span);
        assert!(
            dyn_tps > stat_tps,
            "TPS/GPU: dynamic {dyn_tps} must beat static {stat_tps}"
        );
        assert!(
            dynamic.metrics.p99_ttft() < stat.metrics.p99_ttft(),
            "tail TTFT must improve: dynamic {} vs static {}",
            dynamic.metrics.p99_ttft(),
            stat.metrics.p99_ttft()
        );
        // Skew 0: bit-identical outcome, no migrations, no accounting.
        let s0 = run(0.0, 0);
        let d0 = run(0.0, 8);
        assert_eq!(d0.replacements, 0);
        assert_eq!(d0.remote_fetch_bytes, 0.0);
        assert_eq!(s0.span, d0.span);
        assert_eq!(s0.metrics.median_ttft(), d0.metrics.median_ttft());
    }

    #[test]
    fn sessions_table_covers_the_sweep_and_stays_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = sessions();
        // 3 policies x 2 think times + the churn row + determinism.
        assert_eq!(t.n_rows(), 8);
        let text = t.render();
        for needle in [
            "prefix-affinity",
            "least-outstanding",
            "slo-admission",
            "think=0.5s",
            "think=4s",
            "churn",
            "bit-identical",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn memory_pressure_table_covers_the_sweep_and_stays_deterministic() {
        std::env::set_var("DWDP_QUICK", "1");
        let t = memory_pressure();
        // 3 redundancy rows + 2 budget rows + 1 context row + determinism.
        assert_eq!(t.n_rows(), 7);
        let text = t.render();
        for needle in [
            "local=64",
            "local=128",
            "kv=derived",
            "kv=0.5GB",
            "isl=16k",
            "bit-identical",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    /// The unified-HBM-budget acceptance criterion: under a finite device
    /// budget, raising expert redundancy (`local_experts`) strictly
    /// squeezes the KV side of the hierarchy — the prefix-hit rate falls
    /// monotonically and admissions start deferring — at identical
    /// offered load.  Uses the tiny model on a shrunken device so all
    /// three redundancy levels land in the pressured regime.
    #[test]
    fn raising_redundancy_squeezes_prefix_residency_under_one_budget() {
        use crate::config::PaperModelConfig;
        use crate::util::Json;
        let run = |local: usize| {
            // Tiny device: 2 MB of HBM, 10% headroom; resident weights are
            // 165,888 B x local, KV is 320 B/token, so the derived group
            // budgets are ~18.4k / ~14.2k / ~5.9k tokens at local 2/4/8 —
            // all under the ~16 x 2080-token working set per group.
            let overrides = Json::parse(r#"{"hbm_bytes": 2e6}"#).unwrap();
            let spec = Scenario::fleet()
                .model(PaperModelConfig::tiny())
                .mode(ParallelMode::Dwdp)
                .group(4)
                .groups(3)
                .isl(2048)
                .mnt(16384)
                .osl(32)
                .rate(40.0)
                .requests(48)
                .seed(11)
                .sessions(true)
                .session_turns(4)
                .think_time(0.05)
                .cluster_policy(ClusterPolicy::PrefixAffinity)
                .local_experts(local)
                .hbm_budget(true)
                .json_overrides(overrides)
                .build()
                .unwrap();
            simulate_analytic(&spec).unwrap()
        };
        let lo = run(2);
        let mid = run(4);
        let hi = run(8);
        assert_eq!(lo.offered, hi.offered, "identical closed-loop plans");
        assert!(lo.follow_ups > 0 && hi.follow_ups > 0);
        let rate = |o: &crate::fleet::FleetOutcome| {
            o.prefix_hits as f64 / o.follow_ups.max(1) as f64
        };
        assert!(
            rate(&lo) >= rate(&mid) && rate(&mid) >= rate(&hi),
            "hit rate must fall with redundancy: {} {} {}",
            rate(&lo),
            rate(&mid),
            rate(&hi)
        );
        assert!(
            rate(&lo) > rate(&hi),
            "hit rate must fall strictly across the sweep: {} vs {}",
            rate(&lo),
            rate(&hi)
        );
        assert!(
            hi.deferred_admissions > 0,
            "the tightest budget must defer admissions"
        );
        // The weight side grows exactly with redundancy, and the report
        // surfaces it per rank.
        assert!(hi.hbm_weight_bytes > lo.hbm_weight_bytes);
        assert_eq!(hi.hbm_weight_bytes, PaperModelConfig::tiny().resident_expert_bytes(8));
    }

    /// The PR-6 acceptance criterion, part 1: at equal offered load the
    /// sticky `PrefixAffinity` policy lands strictly more prefix hits and
    /// a strictly lower mean follow-up TTFT than rack-blind
    /// least-outstanding routing.
    #[test]
    fn prefix_affinity_beats_rack_blind_on_follow_up_turns() {
        let run = |policy| {
            // Pin the load regardless of DWDP_QUICK.
            let spec = sessions_scenario(policy, 0.5).requests(64).build().unwrap();
            simulate_analytic(&spec).unwrap()
        };
        let sticky = run(ClusterPolicy::PrefixAffinity);
        let blind = run(ClusterPolicy::LeastOutstandingTokens);
        assert_eq!(sticky.offered, blind.offered, "identical closed-loop plans");
        assert!(sticky.follow_ups > 0 && blind.follow_ups > 0);
        let rate = |o: &crate::fleet::FleetOutcome| {
            o.prefix_hits as f64 / o.follow_ups as f64
        };
        assert!(
            rate(&sticky) > rate(&blind),
            "hit rate: affinity {} must beat rack-blind {}",
            rate(&sticky),
            rate(&blind)
        );
        assert!(
            sticky.follow_up_ttft.mean() < blind.follow_up_ttft.mean(),
            "follow-up TTFT: affinity {} must beat rack-blind {}",
            sticky.follow_up_ttft.mean(),
            blind.follow_up_ttft.mean()
        );
    }

    /// The PR-6 acceptance criterion, part 2: with an infinite think time
    /// (no follow-up is ever scheduled) the closed-loop session path
    /// reproduces the open-loop fleet bit-for-bit — same
    /// `RunReport::to_json()` fingerprint, float for float.  Only the
    /// scenario label differs (it advertises the session knobs).
    #[test]
    fn infinite_think_time_reproduces_the_open_loop_fingerprint() {
        use crate::serving::ServingStack;
        let open = {
            let spec = fleet_scenario(ParallelMode::Dwdp, 4)
                .rate(4.0)
                .requests(64)
                .build()
                .unwrap();
            ServingStack::new(spec, Fidelity::Analytic).run().unwrap()
        };
        let mut closed = {
            let spec = fleet_scenario(ParallelMode::Dwdp, 4)
                .rate(4.0)
                .requests(64)
                .sessions(true)
                .think_time(f64::INFINITY)
                .build()
                .unwrap();
            ServingStack::new(spec, Fidelity::Analytic).run().unwrap()
        };
        assert_eq!(closed.follow_ups, 0, "infinite think time schedules no follow-up");
        closed.scenario = open.scenario.clone();
        assert_eq!(open.to_json().dump(), closed.to_json().dump());
    }
}
