//! Context-only experiments: Fig. 1(b), Table 1, Table 3(a–d), Table 4,
//! the merge-elimination ablation, and the Fig. 4 contention trace.
//!
//! Each regenerator assembles its configuration with the
//! [`crate::serving::Scenario`] builder (starting from the calibrated
//! [`calib::context_scenario`] base) and executes it through a
//! [`ServingStack`] at DES fidelity — the full discrete-event simulator
//! with the DeepSeek-R1 analytic model on GB200 parameters.

use super::calib;
use super::ratio;
use crate::config::ParallelMode;
use crate::model::Category;
use crate::serving::{Fidelity, RunReport, Scenario, ScenarioSpec, ServingStack};
use crate::trace::TraceSink;
use crate::util::table::{f, us, Table};

/// Run one context scenario at DES fidelity.
fn run(scn: Scenario) -> RunReport {
    ServingStack::new(scn.build().expect("context scenario"), Fidelity::Des)
        .run()
        .expect("DES backend")
}

/// E1 — Figure 1(b): DEP synchronization overhead vs per-rank sequence-
/// length imbalance (coefficient of variation of ISLs).
pub fn fig1() -> Table {
    let mut t = Table::new(&[
        "ISL CV (%)",
        "input ratio",
        "Sync (µs/layer)",
        "Comm (µs/layer)",
        "Sync+Comm share (%)",
    ])
    .with_title("Figure 1(b) — DEP4 synchronization overhead vs workload imbalance (ISL 8K)");
    // Uniform[r·ISL, ISL] has CV = (1-r) / (sqrt(3)·(1+r)).
    for ratio_in in [1.0, 0.9, 0.8, 0.65, 0.5] {
        let cv = (1.0 - ratio_in) / (3.0f64.sqrt() * (1.0 + ratio_in)) * 100.0;
        let r = run(
            calib::context_scenario(ParallelMode::Dep, 4)
                .isl(8192)
                .ratio(ratio_in),
        );
        let b = &r.per_layer_breakdown;
        let sync = b.get(Category::Synchronization);
        let comm = b.get(Category::Communication);
        let total = b.critical_path();
        t.row(vec![
            f(cv, 1),
            format!("{ratio_in}"),
            us(sync * 1e6),
            us(comm * 1e6),
            f((sync + comm) / total * 100.0, 1),
        ]);
    }
    t
}

/// E3 — Table 1: context-only per-layer latency breakdown, DEP4 vs DWDP4.
pub fn table1() -> Table {
    let base = |mode| {
        calib::context_scenario(mode, 4)
            .isl(8192)
            .ratio(0.8)
            .mnt(32768)
    };
    let dep = run(base(ParallelMode::Dep));
    // Table 1 profiles the *naive* DWDP baseline: merge-elim off, TDM off.
    let dwdp = run(base(ParallelMode::Dwdp).merge_elim(false).tdm(false));

    let mut t = Table::new(&["Category", "DEP4 (µs)", "DWDP4 (µs)", "Δ/T_DEP4"])
        .with_title("Table 1 — context-only per-layer latency breakdown (ISL 8K, ratio 0.8, MNT 32768)");
    let t_dep_total = dep.per_layer_breakdown.critical_path();
    for cat in Category::all() {
        let a = dep.per_layer_breakdown.get(cat) * 1e6;
        let b = dwdp.per_layer_breakdown.get(cat) * 1e6;
        let delta = if cat == Category::P2pCopy {
            "-".to_string() // off the critical path, like the paper
        } else {
            format!("{:+.2}%", (a - b) / (t_dep_total * 1e6) * 100.0)
        };
        t.row(vec![cat.name().to_string(), us(a), us(b), delta]);
    }
    let dep_total = t_dep_total * 1e6;
    let dwdp_total = dwdp.per_layer_breakdown.critical_path() * 1e6;
    t.row(vec![
        "Iteration Latency".into(),
        us(dep_total),
        us(dwdp_total),
        format!("{:+.2}%", (dep_total - dwdp_total) / dep_total * 100.0),
    ]);
    t
}

/// E6 — Table 3a: speedup vs ISL (MNT fixed 32768).
pub fn table3a() -> Table {
    let mut t = Table::new(&["ISL", "TTFT speedup", "TPS/GPU speedup"])
        .with_title("Table 3a — speedup vs ISL (MNT = 32768)");
    for isl in [1024usize, 8192, 16384, 32768] {
        let base = |mode| calib::context_scenario(mode, 4).isl(isl).mnt(32768);
        let dep = run(base(ParallelMode::Dep));
        let dwdp = run(base(ParallelMode::Dwdp));
        t.row(vec![
            isl.to_string(),
            ratio(dep.median_ttft, dwdp.median_ttft),
            ratio(dwdp.tps_per_gpu, dep.tps_per_gpu),
        ]);
    }
    t
}

/// E7 — Table 3b: speedup vs MNT (ISL fixed 8192).
pub fn table3b() -> Table {
    let mut t = Table::new(&["MNT", "TTFT speedup", "TPS/GPU speedup"])
        .with_title("Table 3b — speedup vs MNT (ISL = 8192)");
    for mnt in [16384usize, 32768] {
        let base = |mode| calib::context_scenario(mode, 4).isl(8192).mnt(mnt);
        let dep = run(base(ParallelMode::Dep));
        let dwdp = run(base(ParallelMode::Dwdp));
        t.row(vec![
            mnt.to_string(),
            ratio(dep.median_ttft, dwdp.median_ttft),
            ratio(dwdp.tps_per_gpu, dep.tps_per_gpu),
        ]);
    }
    t
}

/// E8 — Table 3c: speedup vs ISL standard deviation (imbalance).
pub fn table3c() -> Table {
    let mut t = Table::new(&["ISL/STD", "TTFT speedup", "TPS/GPU speedup"])
        .with_title("Table 3c — speedup vs workload imbalance (ISL = 16384)");
    for std in [0.0f64, 1024.0, 2048.0, 4096.0] {
        let base = |mode| {
            calib::context_scenario(mode, 4)
                .isl(16384)
                .ratio(1.0)
                .isl_std(std)
        };
        let dep = run(base(ParallelMode::Dep));
        let dwdp = run(base(ParallelMode::Dwdp));
        t.row(vec![
            format!("16384/{}", std as usize),
            ratio(dep.median_ttft, dwdp.median_ttft),
            ratio(dwdp.tps_per_gpu, dep.tps_per_gpu),
        ]);
    }
    t
}

/// E9 — Table 3d: speedup vs DWDP group size (DWDP3 vs DWDP4).
pub fn table3d() -> Table {
    let mut t = Table::new(&["Group size", "TTFT speedup", "TPS/GPU speedup"])
        .with_title("Table 3d — speedup vs group size (ISL 16384, MNT 32768)");
    for g in [3usize, 4] {
        let base = |mode| calib::context_scenario(mode, g).isl(16384).mnt(32768);
        let dep = run(base(ParallelMode::Dep));
        let dwdp = run(base(ParallelMode::Dwdp));
        t.row(vec![
            format!("DWDP{g}"),
            ratio(dep.median_ttft, dwdp.median_ttft),
            format!("{:.3}", dwdp.tps_per_gpu / dep.tps_per_gpu),
        ]);
    }
    t
}

/// E10 — §5.2 merge-elimination ablation: DWDP with and without the
/// split-weight kernel (D2D merge on/off), same config as Table 1.
pub fn merge_elim() -> Table {
    let base = || {
        calib::context_scenario(ParallelMode::Dwdp, 4)
            .isl(8192)
            .mnt(32768)
            .tdm(false)
    };
    let naive = run(base().merge_elim(false));
    let elim = run(base().merge_elim(true));
    let mut t = Table::new(&["Variant", "D2D (µs/layer)", "TPS/GPU", "vs naive"])
        .with_title("Merge-elimination ablation (§5.2)");
    t.row(vec![
        "DWDP naive (merge copy)".into(),
        us(naive.per_layer_breakdown.get(Category::D2dCopy) * 1e6),
        f(naive.tps_per_gpu, 0),
        "1.00".into(),
    ]);
    t.row(vec![
        "DWDP + merge elimination".into(),
        us(elim.per_layer_breakdown.get(Category::D2dCopy) * 1e6),
        f(elim.tps_per_gpu, 0),
        ratio(elim.tps_per_gpu, naive.tps_per_gpu),
    ]);
    t
}

/// E11 — Table 4: contention mitigation under short compute windows.
pub fn table4() -> Table {
    let mut t = Table::new(&["ISL Ratio", "MNT", "DEP", "DWDP + Merge Elim.", "Full DWDP"])
        .with_title("Table 4 — context TPS/GPU normalized to DEP (ISL 8K, 1 MB slices)");
    for isl_ratio in [0.5f64, 0.8] {
        for mnt in [16384usize, 32768] {
            let base = |mode| {
                calib::context_scenario(mode, 4)
                    .isl(8192)
                    .ratio(isl_ratio)
                    .mnt(mnt)
            };
            let dep = run(base(ParallelMode::Dep));
            let elim = run(base(ParallelMode::Dwdp).merge_elim(true).tdm(false));
            let full = run(base(ParallelMode::Dwdp).merge_elim(true).tdm(true));
            t.row(vec![
                format!("{isl_ratio}"),
                mnt.to_string(),
                "1.000".into(),
                format!("{:.3}", elim.tps_per_gpu / dep.tps_per_gpu),
                format!("{:.3}", full.tps_per_gpu / dep.tps_per_gpu),
            ]);
        }
    }
    t
}

/// E5 — Figure 4: run a short-window DWDP group with monolithic pulls and
/// emit a Chrome trace exposing the many-to-one bubbles; returns (table of
/// bubble stats, trace).
pub fn fig4_trace() -> (Table, TraceSink) {
    // Paper Fig 4: max_num_tokens 16384, ISLs 4K-8K -> window ~ prefetch.
    let r = run(
        calib::context_scenario(ParallelMode::Dwdp, 4)
            .isl(8192)
            .ratio(0.5)
            .mnt(16384)
            .tdm(false)
            .merge_elim(true)
            .trace(true),
    );
    let trace = r.trace.expect("trace requested from DES backend");
    let mut t = Table::new(&["Rank", "prefetch wait (ms)", "bubbles > 50µs", "longest bubble (µs)"])
        .with_title("Figure 4 — many-to-one contention exposing compute bubbles (no TDM)");
    for (i, wait) in r.rank_prefetch_wait.iter().enumerate() {
        let track = format!("rank{i}.sm");
        // Exposed waits are recorded as explicit "prefetch_wait" spans on
        // the SM track (category "bubble").
        let bubbles: Vec<f64> = trace
            .spans
            .iter()
            .filter(|s| s.track == track && s.cat == "bubble" && s.dur > 50e-6)
            .map(|s| s.dur)
            .collect();
        let longest = bubbles.iter().cloned().fold(0.0f64, f64::max);
        t.row(vec![
            i.to_string(),
            f(wait * 1e3, 2),
            bubbles.len().to_string(),
            us(longest * 1e6),
        ]);
    }
    (t, trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() {
        std::env::set_var("DWDP_QUICK", "1");
    }

    #[test]
    fn table1_dwdp_removes_sync_and_comm() {
        quick();
        let t = table1();
        let s = t.render();
        // DWDP column for Communication and Synchronization must be ~0.
        assert!(s.contains("Synchronization Cost"));
        assert!(s.contains("P2P Copy"));
        assert!(s.contains("Iteration Latency"));
    }

    #[test]
    fn table3b_bigger_mnt_bigger_speedup() {
        quick();
        let t = table3b();
        let csv = t.render_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        let sp = |row: &str| row.split(',').last().unwrap().parse::<f64>().unwrap();
        assert!(sp(rows[1]) >= sp(rows[0]) * 0.98, "{csv}");
    }

    #[test]
    fn table3c_more_imbalance_more_speedup() {
        quick();
        let t = table3c();
        let csv = t.render_csv();
        let rows: Vec<&str> = csv.lines().skip(1).collect();
        let first: f64 = rows[0].split(',').last().unwrap().parse().unwrap();
        let last: f64 = rows[3].split(',').last().unwrap().parse().unwrap();
        assert!(last >= first, "{csv}");
    }

    #[test]
    fn fig4_exposes_bubbles_without_tdm() {
        quick();
        let (t, trace) = fig4_trace();
        assert_eq!(t.n_rows(), 4);
        assert!(!trace.spans.is_empty());
    }

    #[test]
    fn merge_elim_improves_tps() {
        quick();
        let t = merge_elim();
        let csv = t.render_csv();
        let last = csv.lines().last().unwrap();
        let gain: f64 = last.split(',').last().unwrap().parse().unwrap();
        assert!(gain >= 1.0, "{csv}");
    }
}

/// Ablation — TDM slice size: smaller slices interleave better (less
/// head-of-line blocking at the source) but pay more per-request overhead.
/// The paper evaluates 1 MB; this sweep shows why that is a sweet spot.
pub fn ablation_slice_size() -> Table {
    let mut t = Table::new(&["slice", "TPS/GPU", "exposed wait (ms, sum)", "vs 1MiB"])
        .with_title("Ablation — TDM slice size (ISL 8K, ratio 0.5, MNT 16384)");
    let mut results = Vec::new();
    for &slice in &[16usize << 20, 4 << 20, 1 << 20, 256 << 10, 64 << 10] {
        let r = run(
            calib::context_scenario(ParallelMode::Dwdp, 4)
                .ratio(0.5)
                .mnt(16384)
                .slice_bytes(slice),
        );
        let wait: f64 = r.rank_prefetch_wait.iter().sum();
        results.push((slice, r.tps_per_gpu, wait));
    }
    let base = results.iter().find(|&&(sl, _, _)| sl == 1 << 20).unwrap().1;
    for (slice, tps, wait) in results {
        t.row(vec![
            format!("{} KiB", slice >> 10),
            f(tps, 0),
            f(wait * 1e3, 2),
            format!("{:.3}", tps / base),
        ]);
    }
    t
}

/// Ablation — redundant expert placement (§2): more local experts per rank
/// shrink the remote fetch; memory cost rises linearly.
pub fn ablation_redundancy() -> Table {
    let mut t = Table::new(&[
        "local experts/rank",
        "remote fetch (MB/layer)",
        "HBM for MoE (GB)",
        "TPS/GPU",
        "vs minimal",
    ])
    .with_title("Ablation — redundant expert placement, DWDP4 (ISL 8K, MNT 16384)");
    let mut base_tps = 0.0;
    for &local in &[64usize, 96, 128, 192] {
        let spec = calib::context_scenario(ParallelMode::Dwdp, 4)
            .mnt(16384)
            .local_experts(local)
            .build()
            .expect("redundancy scenario");
        let fetch_mb = spec.serving.remote_experts(&spec.model) * spec.model.expert_bytes() / 1e6;
        let hbm_gb = local as f64 * spec.model.expert_bytes() * spec.model.n_moe_layers() as f64
            / 1e9;
        let r = ServingStack::new(spec, Fidelity::Des).run().expect("DES backend");
        if local == 64 {
            base_tps = r.tps_per_gpu;
        }
        t.row(vec![
            local.to_string(),
            f(fetch_mb, 1),
            f(hbm_gb, 1),
            f(r.tps_per_gpu, 0),
            format!("{:.3}", r.tps_per_gpu / base_tps),
        ]);
    }
    t
}

/// The swept scenario specs behind each context regenerator, for the
/// registry's static linter — every configuration a regenerator runs,
/// built (and so validated) without running anything.
///
/// Keep each arm's axes in sync with its regenerator above; the linter
/// covers exactly what is enumerated here.
pub fn registry_specs(id: &str) -> Result<Vec<ScenarioSpec>, String> {
    use ParallelMode::{Dep, Dwdp};
    let mut scns: Vec<Scenario> = Vec::new();
    match id {
        "fig1" => {
            for ratio_in in [1.0, 0.9, 0.8, 0.65, 0.5] {
                scns.push(calib::context_scenario(Dep, 4).isl(8192).ratio(ratio_in));
            }
        }
        "table1" => {
            scns.push(calib::context_scenario(Dep, 4).isl(8192).ratio(0.8).mnt(32768));
            scns.push(
                calib::context_scenario(Dwdp, 4)
                    .isl(8192)
                    .ratio(0.8)
                    .mnt(32768)
                    .merge_elim(false)
                    .tdm(false),
            );
        }
        "table3a" => {
            for isl in [1024usize, 8192, 16384, 32768] {
                for mode in [Dep, Dwdp] {
                    scns.push(calib::context_scenario(mode, 4).isl(isl).mnt(32768));
                }
            }
        }
        "table3b" => {
            for mnt in [16384usize, 32768] {
                for mode in [Dep, Dwdp] {
                    scns.push(calib::context_scenario(mode, 4).isl(8192).mnt(mnt));
                }
            }
        }
        "table3c" => {
            for std in [0.0f64, 1024.0, 2048.0, 4096.0] {
                for mode in [Dep, Dwdp] {
                    scns.push(
                        calib::context_scenario(mode, 4).isl(16384).ratio(1.0).isl_std(std),
                    );
                }
            }
        }
        "table3d" => {
            for g in [3usize, 4] {
                for mode in [Dep, Dwdp] {
                    scns.push(calib::context_scenario(mode, g).isl(16384).mnt(32768));
                }
            }
        }
        "table4" => {
            for isl_ratio in [0.5f64, 0.8] {
                for mnt in [16384usize, 32768] {
                    let base =
                        |mode| calib::context_scenario(mode, 4).isl(8192).ratio(isl_ratio).mnt(mnt);
                    scns.push(base(Dep));
                    scns.push(base(Dwdp).merge_elim(true).tdm(false));
                    scns.push(base(Dwdp).merge_elim(true).tdm(true));
                }
            }
        }
        "merge_elim" => {
            for elim in [false, true] {
                scns.push(
                    calib::context_scenario(Dwdp, 4)
                        .isl(8192)
                        .mnt(32768)
                        .tdm(false)
                        .merge_elim(elim),
                );
            }
        }
        "fig4" => {
            scns.push(
                calib::context_scenario(Dwdp, 4)
                    .isl(8192)
                    .ratio(0.5)
                    .mnt(16384)
                    .tdm(false)
                    .merge_elim(true)
                    .trace(true),
            );
        }
        "ablation_slice" => {
            for &slice in &[16usize << 20, 4 << 20, 1 << 20, 256 << 10, 64 << 10] {
                scns.push(
                    calib::context_scenario(Dwdp, 4).ratio(0.5).mnt(16384).slice_bytes(slice),
                );
            }
        }
        "ablation_redundancy" => {
            for &local in &[64usize, 96, 128, 192] {
                scns.push(calib::context_scenario(Dwdp, 4).mnt(16384).local_experts(local));
            }
        }
        "ablation_fraction" => {
            scns.push(calib::context_scenario(Dep, 4).isl(8192));
            for &frac in &[0.03f64, 0.07, 0.15, 0.3, 0.6, 1.0] {
                scns.push(
                    calib::context_scenario(Dwdp, 4).isl(8192).prefetch_fraction(frac),
                );
            }
        }
        other => return Err(format!("no context specs registered for {other:?}")),
    }
    scns.into_iter().map(|s| s.build()).collect()
}

/// Ablation — sensitivity of the Table-1 calibration to the on-demand
/// prefetch fraction (EXPERIMENTS.md §Calibration).
pub fn ablation_prefetch_fraction() -> Table {
    let mut t = Table::new(&[
        "prefetch fraction",
        "P2P (µs/layer)",
        "DWDP TPS/GPU",
        "vs DEP",
    ])
    .with_title("Ablation — on-demand prefetch fraction (ISL 8K, MNT 32768)");
    let dep = run(calib::context_scenario(ParallelMode::Dep, 4).isl(8192));
    for &frac in &[0.03f64, 0.07, 0.15, 0.3, 0.6, 1.0] {
        let r = run(
            calib::context_scenario(ParallelMode::Dwdp, 4)
                .isl(8192)
                .prefetch_fraction(frac),
        );
        t.row(vec![
            format!("{frac}"),
            us(r.per_layer_breakdown.get(Category::P2pCopy) * 1e6),
            f(r.tps_per_gpu, 0),
            format!("{:.3}", r.tps_per_gpu / dep.tps_per_gpu),
        ]);
    }
    t
}
